//! `cargo bench --bench fig5_overall` — regenerates the paper's fig5.
//! Thin wrapper over [`graphi::coordinator::figures`]; CSV lands in
//! reports/. Set GRAPHI_BENCH_FAST=1 (or pass --fast via the CLI form,
//! `graphi bench fig5 --fast`) for a small-size grid.

use graphi::coordinator::figures;
use graphi::util::bench::{BenchConfig, BenchRunner};
use graphi::models::ModelSize;

fn main() {
    let fast = std::env::var("GRAPHI_BENCH_FAST").as_deref() == Ok("1");
    let sizes: Vec<ModelSize> = if fast {
        vec![ModelSize::Small]
    } else {
        vec![ModelSize::Small, ModelSize::Medium, ModelSize::Large]
    };
    let mut runner = BenchRunner::with_config(
        "fig5",
        BenchConfig { csv_path: Some("reports/fig5.csv".into()), ..BenchConfig::from_env() },
    );
    println!("{}", figures::fig5(&mut runner, &sizes));
    runner.finish();
}
