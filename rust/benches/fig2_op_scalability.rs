//! `cargo bench --bench fig2_op_scalability` — regenerates the paper's fig2.
//! Thin wrapper over [`graphi::coordinator::figures`]; CSV lands in
//! reports/. Set GRAPHI_BENCH_FAST=1 (or pass --fast via the CLI form,
//! `graphi bench fig2 --fast`) for a small-size grid.

use graphi::coordinator::figures;
use graphi::util::bench::{BenchConfig, BenchRunner};

fn main() {
    let mut runner = BenchRunner::with_config(
        "fig2",
        BenchConfig { csv_path: Some("reports/fig2.csv".into()), ..BenchConfig::from_env() },
    );
    println!("{}", figures::fig2(&mut runner));
    runner.finish();
}
