//! `cargo bench --bench table2_scheduler` — regenerates the paper's table2.
//! Thin wrapper over [`graphi::coordinator::figures`]; CSV lands in
//! reports/. Set GRAPHI_BENCH_FAST=1 (or pass --fast via the CLI form,
//! `graphi bench table2 --fast`) for a small-size grid.

use graphi::coordinator::figures;
use graphi::util::bench::{BenchConfig, BenchRunner};
use graphi::models::ModelSize;

fn main() {
    let fast = std::env::var("GRAPHI_BENCH_FAST").as_deref() == Ok("1");
    let size = if fast { ModelSize::Small } else { ModelSize::Medium };
    let mut runner = BenchRunner::with_config(
        "table2",
        BenchConfig { csv_path: Some("reports/table2.csv".into()), ..BenchConfig::from_env() },
    );
    println!("{}", figures::table2(&mut runner, size));
    runner.finish();
}
