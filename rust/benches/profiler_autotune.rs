//! `cargo bench --bench profiler_autotune` — search cost of the
//! successive-halving autotuner versus the flat exhaustive profiler sweep
//! (§4.2), per model:
//!
//! * wall-clock time of each search (the whole search is the unit of work;
//!   the simulated graph executions inside it are the cost being halved);
//! * total profiling iterations spent (the metric column — the quantity
//!   the paper's operator actually pays on real silicon);
//! * the *found-makespan ratio*: the winner of each search re-measured in
//!   a deterministic environment, search/exhaustive, ≤ 1.05 expected.
//!
//! Results merge into `BENCH_scheduler.json` at the repo root (override
//! with `GRAPHI_BENCH_JSON`) with `autotune_iteration_saving_<model>` and
//! `autotune_makespan_ratio_<model>` headline entries per run.

use graphi::engine::{Autotuner, DispatchMode, Engine, GraphiEngine, Profiler, SimEnv};
use graphi::models::{self, ModelKind, ModelSize};
use graphi::util::bench::{merge_into_bench_json, BenchConfig, BenchRunner};

/// The §7.3 model-specific extras both searches seed in.
const EXTRAS: [(usize, usize); 2] = [(3, 21), (6, 10)];

fn main() {
    let mut runner = BenchRunner::with_config(
        "profiler_autotune",
        BenchConfig {
            csv_path: Some("reports/profiler_autotune.csv".into()),
            ..BenchConfig::from_env()
        },
    );

    let mut headlines: Vec<(&'static str, f64)> = Vec::new();
    for (kind, label, saving_key, ratio_key) in [
        (
            ModelKind::Lstm,
            "lstm",
            "autotune_iteration_saving_lstm",
            "autotune_makespan_ratio_lstm",
        ),
        (
            ModelKind::PathNet,
            "pathnet",
            "autotune_iteration_saving_pathnet",
            "autotune_makespan_ratio_pathnet",
        ),
    ] {
        let graph = models::build(kind, ModelSize::Small);
        let env = SimEnv::knl(42);
        // centralized-only axis: the flat profiler it is compared against
        // only sweeps centralized configs, and restricting keeps the
        // iteration-saving trajectory comparable with the PR-2 entries in
        // BENCH_scheduler.json (the dispatch-mode comparison lives in
        // `cargo bench --bench scheduler_hotpath`)
        let tuner = Autotuner {
            extra_configs: EXTRAS.to_vec(),
            dispatch_modes: vec![DispatchMode::Centralized],
            ..Default::default()
        };
        let profiler =
            Profiler { iterations: 3, worker_cores: 64, extra_configs: EXTRAS.to_vec() };

        runner.bench(
            &format!("autotune_search_{label}"),
            &[("nodes", graph.len().to_string())],
            || tuner.search(&graph, &env).best,
        );
        let sh_report = tuner.search(&graph, &env);
        runner.set_metric(sh_report.total_profile_iterations as f64, "iters");

        runner.bench(
            &format!("exhaustive_sweep_{label}"),
            &[("nodes", graph.len().to_string())],
            || profiler.profile(&graph, &env).best,
        );
        let exhaustive = profiler.profile(&graph, &env);
        let exhaustive_iters = profiler.candidates().len() * profiler.iterations;
        runner.set_metric(exhaustive_iters as f64, "iters");

        // winners re-measured noise-free: the quality the saved iterations cost
        let det = SimEnv::knl_deterministic();
        let found =
            GraphiEngine::new(sh_report.best.0, sh_report.best.1).run(&graph, &det).makespan_us;
        let sweep = GraphiEngine::new(exhaustive.best.0, exhaustive.best.1)
            .run(&graph, &det)
            .makespan_us;
        runner.record(
            &format!("autotune_best_makespan_{label}"),
            &[("config", format!("{}x{}", sh_report.best.0, sh_report.best.1))],
            found,
        );
        runner.record(
            &format!("exhaustive_best_makespan_{label}"),
            &[("config", format!("{}x{}", exhaustive.best.0, exhaustive.best.1))],
            sweep,
        );
        headlines.push((
            saving_key,
            1.0 - sh_report.total_profile_iterations as f64 / exhaustive_iters as f64,
        ));
        headlines.push((ratio_key, found / sweep));
    }

    runner.finish();
    merge_into_bench_json(&runner, &headlines);
}
