//! `cargo bench --bench fig3_pinning` — regenerates the paper's fig3.
//! Thin wrapper over [`graphi::coordinator::figures`]; CSV lands in
//! reports/. Set GRAPHI_BENCH_FAST=1 (or pass --fast via the CLI form,
//! `graphi bench fig3 --fast`) for a small-size grid.

use graphi::coordinator::figures;
use graphi::util::bench::{BenchConfig, BenchRunner};

fn main() {
    let mut runner = BenchRunner::with_config(
        "fig3",
        BenchConfig { csv_path: Some("reports/fig3.csv".into()), ..BenchConfig::from_env() },
    );
    println!("{}", figures::fig3(&mut runner));
    runner.finish();
}
