//! `cargo bench --bench scheduler_hotpath` — real wall-clock microbenches
//! of the L3 scheduler's hot data structures (not simulated time):
//!
//! * level max-heap push/pop throughput at LSTM-scale ready-set sizes
//! * idle-bitmap scan (the §5.2 bit-scan)
//! * SPSC ring push/pop hand-off
//! * end-to-end dispatch decisions/second through the threaded engine
//!
//! These are the §Perf numbers for Layer 3: the scheduler must sustain
//! orders of magnitude more decisions/second than the op arrival rate
//! (ops of 10µs–10ms ⇒ ≤ ~6.6M ops/s per 68 cores worst case).

use graphi::engine::ready::ReadySet;
use graphi::engine::ring::SpscRing;
use graphi::engine::scheduler::IdleBitmap;
use graphi::engine::Policy;
use graphi::models::{self, ModelKind, ModelSize};
use graphi::runtime::ThreadedGraphi;
use graphi::util::bench::{BenchConfig, BenchRunner};
use graphi::util::rng::Rng;

fn main() {
    let mut runner = BenchRunner::with_config(
        "scheduler_hotpath",
        BenchConfig {
            csv_path: Some("reports/scheduler_hotpath.csv".into()),
            ..BenchConfig::from_env()
        },
    );

    // -- ready-set heap at realistic occupancy --------------------------
    let mut rng = Rng::new(1);
    let levels: Vec<f64> = (0..4096).map(|_| rng.uniform(0.0, 1e6)).collect();
    let n_ops = 4096u32;
    runner.bench("heap_push_pop_4096", &[], || {
        let mut ready = ReadySet::new(Policy::CriticalPathFirst, levels.clone(), 0);
        for i in 0..n_ops {
            ready.push(i);
        }
        let mut acc = 0u32;
        while let Some(v) = ready.pop() {
            acc = acc.wrapping_add(v);
        }
        acc
    });
    let per_op =
        runner.results.last().unwrap().summary.mean / (2.0 * n_ops as f64);
    runner.set_metric(1.0 / per_op, "Mops/µs⁻¹");

    // -- bitmap scan ------------------------------------------------------
    runner.bench("bitmap_scan_64", &[], || {
        let mut bm = IdleBitmap::new(64);
        let mut found = 0usize;
        for _ in 0..64 {
            let e = bm.first_idle().unwrap();
            bm.set_busy(e);
            found += e;
        }
        for e in 0..64 {
            bm.set_idle(e);
        }
        found
    });

    // -- SPSC ring hand-off ------------------------------------------------
    runner.bench("ring_handoff_1024", &[], || {
        let ring: SpscRing<u32> = SpscRing::new(1);
        let mut acc = 0u32;
        for i in 0..1024u32 {
            ring.push(i).unwrap();
            acc = acc.wrapping_add(ring.pop().unwrap());
        }
        acc
    });

    // -- threaded engine dispatch rate --------------------------------------
    let graph = models::build(ModelKind::Lstm, ModelSize::Small);
    let levels: Vec<f64> = vec![1.0; graph.len()];
    runner.bench(
        "threaded_dispatch_lstm_small",
        &[("nodes", graph.len().to_string())],
        || {
            let engine = ThreadedGraphi::new(2);
            engine.run(&graph, &levels, |_| {}).dispatches
        },
    );
    let mean_us = runner.results.last().unwrap().summary.mean;
    runner.set_metric(graph.len() as f64 / mean_us, "dispatch/µs");

    println!("{}", runner.report());
    runner.finish();
}
