//! `cargo bench --bench scheduler_hotpath` — real wall-clock microbenches
//! of the L3 scheduler's hot data structures (not simulated time):
//!
//! * packed d-ary ready-heap push/pop throughput at 256 / 4 Ki / 64 Ki
//!   occupancy, plus the seed's `BinaryHeap<HeapEntry>` re-implemented
//!   inline (`heap_push_pop_4096_legacy`) so the before/after ratio is
//!   measurable from a single run
//! * idle-bitmap scan (the §5.2 bit-scan)
//! * SPSC ring hand-off: same-thread, two-real-thread ping-pong, and
//!   two-thread batched streaming
//! * work-stealing deque ops: owner push/pop churn and a 2-thread
//!   owner-vs-thief drain (the decentralized dispatch hot structures)
//! * end-to-end dispatch decisions/second through the threaded engine at
//!   2 / 4 / 8 executors, **centralized vs decentralized** on the same
//!   small-op-heavy trace — the PR-3 headline pair
//!   (`dispatch_decentral_speedup_{2,4,8}exec`); engines constructed
//!   **outside** the timed closure, so the benchmark measures the
//!   scheduler, not the allocator
//!
//! These are the §Perf numbers for Layer 3: the scheduler must sustain
//! orders of magnitude more decisions/second than the op arrival rate
//! (ops of 10µs–10ms ⇒ ≤ ~6.6M ops/s per 68 cores worst case).
//!
//! Results are also merged into `BENCH_scheduler.json` at the repo root
//! (override with `GRAPHI_BENCH_JSON`), appending one timestamped entry
//! per run so the perf trajectory accumulates.

use std::collections::BinaryHeap;
use std::sync::Arc;

use graphi::engine::ready::ReadySet;
use graphi::engine::ring::SpscRing;
use graphi::engine::scheduler::IdleBitmap;
use graphi::engine::worksteal::{Steal, WorkStealDeque};
use graphi::engine::{DispatchMode, Policy};
use graphi::models::{self, ModelKind, ModelSize};
use graphi::runtime::ThreadedGraphi;
use graphi::util::bench::{merge_into_bench_json, BenchConfig, BenchRunner};
use graphi::util::rng::Rng;

/// The seed repo's ready-heap entry (24 bytes, f64 comparisons), kept here
/// verbatim as the measurement baseline for the packed-u64 d-ary heap.
struct LegacyHeapEntry {
    priority: f64,
    seq: u64,
    node: u32,
}

impl PartialEq for LegacyHeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for LegacyHeapEntry {}
impl PartialOrd for LegacyHeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for LegacyHeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .total_cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Spin briefly, then yield — keeps the 2-thread benches honest on
/// oversubscribed (e.g. 1-core CI) hosts where pure spinning deadlocks a
/// timeslice.
#[inline]
fn backoff(spins: &mut u32) {
    *spins += 1;
    if *spins < 64 {
        std::hint::spin_loop();
    } else {
        *spins = 0;
        std::thread::yield_now();
    }
}

fn main() {
    let mut runner = BenchRunner::with_config(
        "scheduler_hotpath",
        BenchConfig {
            csv_path: Some("reports/scheduler_hotpath.csv".into()),
            ..BenchConfig::from_env()
        },
    );

    // -- ready-set heap at realistic occupancies ------------------------
    // levels are generated once; the ReadySet is constructed once per
    // occupancy and reused (it drains empty every iteration), so the timed
    // body is purely push/pop traffic
    let mut rng = Rng::new(1);
    for &occ in &[256usize, 4096, 65536] {
        let levels: Arc<[f64]> =
            (0..occ).map(|_| rng.uniform(0.0, 1e6)).collect::<Vec<f64>>().into();
        let mut ready = ReadySet::new(Policy::CriticalPathFirst, Arc::clone(&levels), 0);
        runner.bench(&format!("heap_push_pop_{occ}"), &[("occupancy", occ.to_string())], || {
            for i in 0..occ as u32 {
                ready.push(i);
            }
            let mut acc = 0u32;
            while let Some(v) = ready.pop() {
                acc = acc.wrapping_add(v);
            }
            acc
        });
        let per_op = runner.results.last().unwrap().summary.mean / (2.0 * occ as f64);
        runner.set_metric(1.0 / per_op, "ops/µs");

        if occ == 4096 {
            // the pre-PR structure, measured under identical traffic
            let mut heap: BinaryHeap<LegacyHeapEntry> = BinaryHeap::new();
            runner.bench(
                "heap_push_pop_4096_legacy",
                &[("occupancy", occ.to_string())],
                || {
                    for i in 0..occ as u32 {
                        heap.push(LegacyHeapEntry {
                            priority: levels[i as usize],
                            seq: i as u64,
                            node: i,
                        });
                    }
                    let mut acc = 0u32;
                    while let Some(e) = heap.pop() {
                        acc = acc.wrapping_add(e.node);
                    }
                    acc
                },
            );
            let per_op = runner.results.last().unwrap().summary.mean / (2.0 * occ as f64);
            runner.set_metric(1.0 / per_op, "ops/µs");
        }
    }

    // -- bitmap scan ------------------------------------------------------
    runner.bench("bitmap_scan_64", &[], || {
        let mut bm = IdleBitmap::new(64);
        let mut found = 0usize;
        for _ in 0..64 {
            let e = bm.first_idle().unwrap();
            bm.set_busy(e);
            found += e;
        }
        for e in 0..64 {
            bm.set_idle(e);
        }
        found
    });

    // -- SPSC ring hand-off, same thread -----------------------------------
    let ring: SpscRing<u32> = SpscRing::new(1);
    runner.bench("ring_handoff_1024", &[], || {
        let mut acc = 0u32;
        for i in 0..1024u32 {
            ring.push(i).unwrap();
            acc = acc.wrapping_add(ring.pop().unwrap());
        }
        acc
    });

    // -- SPSC ring ping-pong across two real threads ------------------------
    // round-trip latency through a pair of depth-1 rings; the partner
    // thread echoes every item back. Rings are constructed outside the
    // timed closure (they drain empty each iteration); the per-iteration
    // thread spawn+join is amortised over the roundtrip count.
    let n_pingpong = 5_000u32;
    let fwd: SpscRing<u32> = SpscRing::new(1);
    let bwd: SpscRing<u32> = SpscRing::new(1);
    runner.bench("ring_pingpong_2thread", &[("roundtrips", n_pingpong.to_string())], || {
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut spins = 0u32;
                for _ in 0..n_pingpong {
                    let v = loop {
                        if let Some(x) = fwd.pop() {
                            break x;
                        }
                        backoff(&mut spins);
                    };
                    let mut item = v;
                    while let Err(back) = bwd.push(item) {
                        item = back;
                        backoff(&mut spins);
                    }
                }
            });
            let mut spins = 0u32;
            let mut acc = 0u32;
            for i in 0..n_pingpong {
                let mut item = i;
                while let Err(back) = fwd.push(item) {
                    item = back;
                    backoff(&mut spins);
                }
                let v = loop {
                    if let Some(x) = bwd.pop() {
                        break x;
                    }
                    backoff(&mut spins);
                };
                acc = acc.wrapping_add(v);
            }
            acc
        })
    });

    // -- SPSC ring two-thread streaming through the batch APIs --------------
    // ring constructed outside the timed closure; 100k items amortise the
    // per-iteration thread spawn to noise
    let n_stream = 100_000u64;
    let ring: SpscRing<u64> = SpscRing::new(256);
    runner.bench("ring_stream_2thread_batch", &[("items", n_stream.to_string())], || {
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut spins = 0u32;
                let mut next = 0u64;
                while next < n_stream {
                    let hi = (next + 64).min(n_stream);
                    let mut batch = next..hi;
                    let pushed = ring.push_batch(&mut batch) as u64;
                    next += pushed;
                    if pushed == 0 {
                        backoff(&mut spins);
                    }
                }
            });
            let mut spins = 0u32;
            let mut out: Vec<u64> = Vec::with_capacity(64);
            let mut received = 0u64;
            let mut acc = 0u64;
            while received < n_stream {
                out.clear();
                let popped = ring.pop_batch(&mut out, 64);
                if popped == 0 {
                    backoff(&mut spins);
                    continue;
                }
                received += popped as u64;
                for &v in &out {
                    acc = acc.wrapping_add(v);
                }
            }
            acc
        })
    });
    let mean_us = runner.results.last().unwrap().summary.mean;
    runner.set_metric(n_stream as f64 / mean_us, "items/µs");

    // -- work-stealing deque: owner churn + 2-thread owner-vs-thief --------
    let deque: WorkStealDeque = WorkStealDeque::new(4096);
    runner.bench("worksteal_push_pop_4096", &[], || {
        for i in 0..4096u64 {
            deque.push(i).unwrap();
        }
        let mut acc = 0u64;
        while let Some(v) = deque.pop() {
            acc = acc.wrapping_add(v);
        }
        acc
    });
    let per_op = runner.results.last().unwrap().summary.mean / (2.0 * 4096.0);
    runner.set_metric(1.0 / per_op, "ops/µs");

    // owner produces and LIFO-drains while one thief strips the top end;
    // a done flag (set only after the owner's final drain) bounds the
    // thief's exit so the bench cannot hang on starved schedules
    let n_steal = 100_000u64;
    let steal_deque: WorkStealDeque = WorkStealDeque::new(1024);
    let steal_done = std::sync::atomic::AtomicBool::new(false);
    runner.bench("worksteal_2thread_drain", &[("items", n_steal.to_string())], || {
        use std::sync::atomic::Ordering;
        steal_done.store(false, Ordering::Relaxed);
        std::thread::scope(|s| {
            let thief = s.spawn(|| {
                let mut acc = 0u64;
                let mut spins = 0u32;
                loop {
                    match steal_deque.steal() {
                        Steal::Success(v) => acc = acc.wrapping_add(v),
                        Steal::Retry => std::hint::spin_loop(),
                        Steal::Empty => {
                            if steal_done.load(Ordering::Acquire) && steal_deque.is_empty() {
                                return acc;
                            }
                            backoff(&mut spins);
                        }
                    }
                }
            });
            let mut acc = 0u64;
            for i in 1..=n_steal {
                let mut key = i;
                while let Err(back) = steal_deque.push(key) {
                    key = back;
                    // full: help drain from the owner end
                    if let Some(v) = steal_deque.pop() {
                        acc = acc.wrapping_add(v);
                    }
                }
            }
            while let Some(v) = steal_deque.pop() {
                acc = acc.wrapping_add(v);
            }
            steal_done.store(true, Ordering::Release);
            acc.wrapping_add(thief.join().unwrap())
        })
    });
    let mean_us = runner.results.last().unwrap().summary.mean;
    runner.set_metric(n_steal as f64 / mean_us, "items/µs");

    // -- threaded engine dispatch rate at 2 / 4 / 8 executors ---------------
    // centralized vs decentralized on the same small-op-heavy trace (LSTM
    // small, no-op work bodies ⇒ dispatch throughput is the bottleneck).
    // The centralized names keep their PR-1 spelling so the JSON
    // trajectory stays comparable across PRs.
    let graph = models::build(ModelKind::Lstm, ModelSize::Small);
    let levels: Arc<[f64]> = vec![1.0f64; graph.len()].into();
    for &execs in &[2usize, 4, 8] {
        for mode in DispatchMode::ALL {
            // engine constructed outside the timed closure; levels shared
            // via Arc, so runs pay no O(nodes) copy (PR-3 satellite)
            let engine = ThreadedGraphi::new(execs).with_dispatch(mode);
            let name = match (execs, mode) {
                (2, DispatchMode::Centralized) => "threaded_dispatch_lstm_small".to_string(),
                (_, DispatchMode::Centralized) => {
                    format!("threaded_dispatch_lstm_small_{execs}exec")
                }
                (_, DispatchMode::Decentralized) => {
                    format!("threaded_dispatch_decentral_lstm_small_{execs}exec")
                }
            };
            runner.bench(
                &name,
                &[
                    ("nodes", graph.len().to_string()),
                    ("executors", execs.to_string()),
                    ("dispatch", mode.name().to_string()),
                ],
                || engine.run(&graph, Arc::clone(&levels), |_| {}).unwrap().dispatches,
            );
            let mean_us = runner.results.last().unwrap().summary.mean;
            runner.set_metric(graph.len() as f64 / mean_us, "dispatch/µs");
        }
    }

    // -- NUMA steal locality + idle backoff (PR 4) --------------------------
    // Not wall-clock benches: these are behaviour counters the tentpole
    // promises — the same-domain steal fraction the simulator's victim
    // ranking achieves on a 2-domain fleet (small-op-heavy 640-node
    // graph), and how often idle executors actually reach the park stage
    // instead of burning their cores. Recorded as run headlines
    // (numa_steal_local_fraction_* / backoff_idle_*), superseding the
    // ANALYTIC entry in BENCH_scheduler.json once a toolchain runs this.
    let numa_fraction = {
        use graphi::engine::{Engine, GraphiEngine, SimEnv};
        use graphi::graph::op::{EwKind, OpKind};
        use graphi::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let mut prev: Vec<u32> = Vec::new();
        for layer in 0..40 {
            let mut this = Vec::new();
            for i in 0..16 {
                let n = b.add(
                    format!("l{layer}n{i}"),
                    OpKind::Elementwise { n: 2_000, arity: 2, kind: EwKind::Arith },
                );
                if let Some(&p) = prev.get(i % prev.len().max(1)) {
                    b.depend(p, n);
                }
                this.push(n);
            }
            prev = this;
        }
        let wide = b.build().unwrap();
        let mut env = SimEnv::knl_deterministic();
        env.cost.machine = graphi::cost::machine::Machine {
            numa_domains: 2,
            ..graphi::cost::machine::Machine::knl7250()
        };
        let r = GraphiEngine::new(8, 8)
            .with_dispatch(DispatchMode::Decentralized)
            .run(&wide, &env);
        if r.metrics.steals > 0 {
            (r.metrics.steals - r.metrics.steals_cross_domain) as f64 / r.metrics.steals as f64
        } else {
            1.0
        }
    };

    // idle-heavy shape: a 64-op chain keeps one executor busy (~100 µs of
    // spin work per op) while the rest idle long enough to walk
    // spin → yield → park; parks counted per fleet size
    let mut backoff_parks = Vec::new();
    {
        use graphi::graph::op::OpKind;
        use graphi::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let mut prev = b.add("c0", OpKind::Scalar);
        for i in 1..64 {
            let n = b.add(format!("c{i}"), OpKind::Scalar);
            b.depend(prev, n);
            prev = n;
        }
        let chain = b.build().unwrap();
        let chain_levels: Arc<[f64]> = vec![1.0f64; chain.len()].into();
        for &execs in &[2usize, 4, 8] {
            let engine = ThreadedGraphi::new(execs);
            let r = engine
                .run(&chain, Arc::clone(&chain_levels), |_| {
                    let t0 = std::time::Instant::now();
                    while t0.elapsed() < std::time::Duration::from_micros(100) {
                        std::hint::spin_loop();
                    }
                })
                .unwrap();
            backoff_parks.push((execs, r.parks as f64));
        }
    }

    println!("{}", runner.report());
    runner.finish();
    let mean_of = |name: &str| {
        runner.results.iter().find(|r| r.name == name).map(|r| r.summary.mean)
    };
    let mut headlines = Vec::new();
    headlines.push(("numa_steal_local_fraction_640node_2dom", numa_fraction));
    let park_keys = [
        (2usize, "backoff_idle_parks_chain64_2exec"),
        (4, "backoff_idle_parks_chain64_4exec"),
        (8, "backoff_idle_parks_chain64_8exec"),
    ];
    for (execs, parks) in &backoff_parks {
        if let Some(&(_, key)) = park_keys.iter().find(|(e, _)| e == execs) {
            headlines.push((key, *parks));
        }
    }
    // speedup headline: packed heap vs the inlined legacy BinaryHeap
    if let (Some(new), Some(old)) = (mean_of("heap_push_pop_4096"), mean_of("heap_push_pop_4096_legacy")) {
        if new > 0.0 {
            headlines.push(("heap_push_pop_4096_speedup_vs_legacy", old / new));
        }
    }
    // PR-3 headline pair: decentralized vs centralized dispatch throughput
    let central_name = |execs: usize| {
        if execs == 2 {
            "threaded_dispatch_lstm_small".to_string()
        } else {
            format!("threaded_dispatch_lstm_small_{execs}exec")
        }
    };
    let speedup_keys = [
        (2usize, "dispatch_decentral_speedup_2exec"),
        (4, "dispatch_decentral_speedup_4exec"),
        (8, "dispatch_decentral_speedup_8exec"),
    ];
    for (execs, key) in speedup_keys {
        let central = mean_of(&central_name(execs));
        let decentral = mean_of(&format!("threaded_dispatch_decentral_lstm_small_{execs}exec"));
        if let (Some(c), Some(d)) = (central, decentral) {
            if d > 0.0 {
                headlines.push((key, c / d));
            }
        }
    }
    merge_into_bench_json(&runner, &headlines);
}
