//! `cargo bench --bench ablations` — regenerates the paper's ablations.
//! Thin wrapper over [`graphi::coordinator::figures`]; CSV lands in
//! reports/. Set GRAPHI_BENCH_FAST=1 (or pass --fast via the CLI form,
//! `graphi bench ablations --fast`) for a small-size grid.

use graphi::coordinator::figures;
use graphi::util::bench::{BenchConfig, BenchRunner};

fn main() {
    let mut runner = BenchRunner::with_config(
        "ablations",
        BenchConfig { csv_path: Some("reports/ablations.csv".into()), ..BenchConfig::from_env() },
    );
    println!("{}", figures::ablations(&mut runner));
    runner.finish();
}
