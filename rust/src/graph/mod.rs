//! Computation-graph IR.
//!
//! A deep-learning model compiles to a directed acyclic graph whose nodes
//! are typed operations ([`OpKind`]) and whose edges are data dependencies
//! (§2 of the paper). Everything downstream — the cost model, the
//! simulator, the engines — consumes this IR.
//!
//! * [`op`]      — operation kinds with flop/byte accounting
//! * [`dag`]     — the frozen CSR graph + topological utilities
//! * [`builder`] — mutable graph construction API
//! * [`levels`]  — critical-path "level" values (§4.3)
//! * [`stats`]   — parallelism profile and op census
//! * [`dot`]     — Graphviz export for debugging

pub mod builder;
pub mod dag;
pub mod dot;
pub mod levels;
pub mod memory;
pub mod op;
pub mod stats;

pub use builder::GraphBuilder;
pub use dag::{AtomicDepTracker, Graph, GraphError, NodeId};
pub use levels::{critical_path, depths, levels, phase_members, width_phases, Phase};
pub use memory::{plan as plan_memory, MemoryPlan};
pub use op::{EwKind, OpKind};
pub use stats::GraphStats;
