//! Graph census + parallelism profile.
//!
//! Answers the question the profiler (and §7.3's analysis) needs: *how much
//! intrinsic parallelism does this graph have?* The "width profile" is the
//! number of ops at each depth; the maximum/average width bounds the useful
//! executor count.

use std::collections::BTreeMap;

use super::dag::{Graph, NodeId};
use super::op::OpClass;

/// Aggregate information about a graph.
#[derive(Debug, Clone)]
pub struct GraphStats {
    pub nodes: usize,
    pub edges: usize,
    pub total_flops: f64,
    pub total_bytes: f64,
    /// Longest path length in *hops* (unit durations).
    pub depth: usize,
    /// Number of ops per depth layer.
    pub width_profile: Vec<usize>,
    pub max_width: usize,
    pub avg_width: f64,
    /// Count of ops by scalability class.
    pub class_census: BTreeMap<&'static str, usize>,
    pub tiny_ops: usize,
}

impl GraphStats {
    pub fn compute(graph: &Graph) -> GraphStats {
        // depth of each node = 1 + max(depth of preds)
        let order = graph.topo_order();
        let mut depth = vec![0usize; graph.len()];
        for &v in &order {
            let d = graph
                .preds(v)
                .iter()
                .map(|&p| depth[p as usize] + 1)
                .max()
                .unwrap_or(0);
            depth[v as usize] = d;
        }
        let max_depth = depth.iter().copied().max().unwrap_or(0);
        let mut width_profile = vec![0usize; max_depth + 1];
        for v in 0..graph.len() {
            width_profile[depth[v]] += 1;
        }
        let max_width = width_profile.iter().copied().max().unwrap_or(0);
        let avg_width = graph.len() as f64 / width_profile.len() as f64;

        let mut class_census: BTreeMap<&'static str, usize> = BTreeMap::new();
        let mut tiny_ops = 0usize;
        for node in graph.nodes() {
            *class_census.entry(node.kind.class().name()).or_insert(0) += 1;
            if node.kind.is_tiny() {
                tiny_ops += 1;
            }
        }

        GraphStats {
            nodes: graph.len(),
            edges: graph.num_edges(),
            total_flops: graph.total_flops(),
            total_bytes: graph.total_bytes(),
            depth: max_depth + 1,
            width_profile,
            max_width,
            avg_width,
            class_census,
            tiny_ops,
        }
    }

    /// A rough static estimate of the useful executor count: the average
    /// width of the non-trivial layers. §7.3 notes the optimal executor
    /// count "is related to the structure of the model" and can be inferred
    /// statically.
    pub fn suggested_executors(&self) -> usize {
        // median width is robust to the thin head/tail of training graphs
        let mut widths: Vec<usize> = self.width_profile.iter().copied().filter(|&w| w > 0).collect();
        widths.sort_unstable();
        let median = widths[widths.len() / 2];
        median.clamp(1, 64)
    }

    /// Render a one-screen summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "nodes={} edges={} depth={} max_width={} avg_width={:.1}\n",
            self.nodes, self.edges, self.depth, self.max_width, self.avg_width
        ));
        out.push_str(&format!(
            "flops={} bytes={} tiny_ops={}\n",
            crate::util::fmt_si(self.total_flops),
            crate::util::fmt_si(self.total_bytes),
            self.tiny_ops
        ));
        out.push_str("classes:");
        for (class, count) in &self.class_census {
            out.push_str(&format!(" {class}={count}"));
        }
        out.push('\n');
        out
    }
}

/// Per-node depth (layer index), exposed for trace visualizations.
pub fn node_depths(graph: &Graph) -> Vec<usize> {
    let order = graph.topo_order();
    let mut depth = vec![0usize; graph.len()];
    for &v in &order {
        depth[v as usize] = graph
            .preds(v)
            .iter()
            .map(|&p| depth[p as usize] + 1)
            .max()
            .unwrap_or(0);
    }
    depth
}

/// Number of ops whose class is `class` that can run concurrently at some
/// depth (used in tests asserting PathNet has 6 parallel conv modules).
pub fn max_parallel_of_class(graph: &Graph, class: OpClass) -> usize {
    let depths = node_depths(graph);
    let mut by_depth: BTreeMap<usize, usize> = BTreeMap::new();
    for v in 0..graph.len() as NodeId {
        if graph.node(v).kind.class() == class {
            *by_depth.entry(depths[v as usize]).or_insert(0) += 1;
        }
    }
    by_depth.values().copied().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    fn wide_graph() -> Graph {
        // src -> {p1..p4} -> sink
        let mut b = GraphBuilder::new();
        let src = b.add("src", OpKind::Scalar);
        let mids: Vec<_> = (0..4)
            .map(|i| b.add_after(format!("p{i}"), OpKind::MatMul { m: 64, k: 64, n: 64 }, &[src]))
            .collect();
        b.add_after("sink", OpKind::Scalar, &mids);
        b.build().unwrap()
    }

    #[test]
    fn width_profile() {
        let s = GraphStats::compute(&wide_graph());
        assert_eq!(s.depth, 3);
        assert_eq!(s.width_profile, vec![1, 4, 1]);
        assert_eq!(s.max_width, 4);
        assert_eq!(s.nodes, 6);
        assert_eq!(s.edges, 8);
    }

    #[test]
    fn census_counts_classes() {
        let s = GraphStats::compute(&wide_graph());
        assert_eq!(s.class_census["gemm"], 4);
        assert_eq!(s.class_census["tiny"], 2);
    }

    #[test]
    fn suggested_executors_reasonable() {
        let s = GraphStats::compute(&wide_graph());
        let k = s.suggested_executors();
        assert!((1..=4).contains(&k), "suggested {k}");
    }

    #[test]
    fn depths_monotone_along_edges() {
        let g = wide_graph();
        let d = node_depths(&g);
        for v in 0..g.len() as NodeId {
            for &s in g.succs(v) {
                assert!(d[s as usize] > d[v as usize]);
            }
        }
    }

    #[test]
    fn max_parallel_gemm() {
        let g = wide_graph();
        assert_eq!(max_parallel_of_class(&g, OpClass::Gemm), 4);
        assert_eq!(max_parallel_of_class(&g, OpClass::Conv), 0);
    }

    #[test]
    fn render_contains_key_fields() {
        let text = GraphStats::compute(&wide_graph()).render();
        assert!(text.contains("nodes=6"));
        assert!(text.contains("gemm=4"));
    }
}
