//! Critical-path "level" values (§4.3 of the paper).
//!
//! > "…we can derive a level value for each operation, which is defined as
//! > the longest accumulated time from this operation to the end (sink
//! > point) of the computation graph."
//!
//! The scheduler sorts ready operations by decreasing level so the critical
//! path never starves. Levels are computed once per profiling update in
//! reverse topological order, O(V + E).

use super::dag::{Graph, NodeId};

/// Compute level values given per-node estimated durations (µs).
///
/// `level(v) = dur(v) + max(level(s) for s in succs(v))`, 0-max for sinks.
pub fn levels(graph: &Graph, durations: &[f64]) -> Vec<f64> {
    assert_eq!(durations.len(), graph.len(), "one duration per node");
    let order = graph.topo_order();
    let mut level = vec![0.0f64; graph.len()];
    for &v in order.iter().rev() {
        let mut best = 0.0f64;
        for &s in graph.succs(v) {
            best = best.max(level[s as usize]);
        }
        level[v as usize] = durations[v as usize] + best;
    }
    level
}

/// The critical path itself: a source-to-sink node sequence achieving the
/// maximum accumulated duration. Useful for traces and for the §7.4
/// wavefront analysis.
pub fn critical_path(graph: &Graph, durations: &[f64]) -> Vec<NodeId> {
    let level = levels(graph, durations);
    let mut current = (0..graph.len() as NodeId)
        .filter(|&v| graph.in_degree(v) == 0)
        .max_by(|&a, &b| level[a as usize].total_cmp(&level[b as usize]))
        .expect("non-empty graph has a source");
    let mut path = vec![current];
    loop {
        let next = graph
            .succs(current)
            .iter()
            .copied()
            .max_by(|&a, &b| level[a as usize].total_cmp(&level[b as usize]));
        match next {
            Some(n) => {
                path.push(n);
                current = n;
            }
            None => return path,
        }
    }
}

/// Topological depth of each node: 0 for sources,
/// `1 + max(depth(pred))` otherwise. Where [`levels`] measures the time
/// *remaining to the sink* (§4.3), depth measures the hop distance *from
/// the sources* — the axis the per-phase dispatch split works along,
/// because a node's predecessors always sit at strictly smaller depths.
pub fn depths(graph: &Graph) -> Vec<u32> {
    let order = graph.topo_order();
    let mut depth = vec![0u32; graph.len()];
    for &v in &order {
        for &p in graph.preds(v) {
            depth[v as usize] = depth[v as usize].max(depth[p as usize] + 1);
        }
    }
    depth
}

/// One width phase: a maximal run of consecutive depths that are all on
/// the same side of the width threshold (see [`width_phases`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Phase {
    /// First depth of the band (inclusive).
    pub first_depth: u32,
    /// Last depth of the band (inclusive).
    pub last_depth: u32,
    /// Total nodes across the band's depths.
    pub nodes: usize,
    /// Widest single depth in the band.
    pub max_width: usize,
    /// `max_width >= threshold`: a wide phase (decentralized dispatch's
    /// home turf); narrow phases are chain-like (the centralized
    /// scheduler's LW lane shines there).
    pub wide: bool,
}

/// Split the graph into **width phases**: per-depth node counts are
/// classified wide/narrow against `threshold` (ops-per-depth ≥ threshold)
/// and consecutive same-class depths merge into one phase. Every node
/// belongs to exactly one phase, and all of a node's predecessors are in
/// the same or an earlier phase — which is what lets the runtime put a
/// barrier at phase boundaries and switch dispatch architecture there
/// ([`crate::engine::PhasePlan`]).
pub fn width_phases(graph: &Graph, threshold: usize) -> Vec<Phase> {
    let threshold = threshold.max(1);
    let depth = depths(graph);
    let max_depth = depth.iter().copied().max().unwrap_or(0) as usize;
    let mut width = vec![0usize; max_depth + 1];
    for &d in &depth {
        width[d as usize] += 1;
    }
    let mut phases: Vec<Phase> = Vec::new();
    for (d, &w) in width.iter().enumerate() {
        let wide = w >= threshold;
        match phases.last_mut() {
            Some(p) if p.wide == wide => {
                p.last_depth = d as u32;
                p.nodes += w;
                p.max_width = p.max_width.max(w);
            }
            _ => phases.push(Phase {
                first_depth: d as u32,
                last_depth: d as u32,
                nodes: w,
                max_width: w,
                wide,
            }),
        }
    }
    phases
}

/// The nodes of each phase of [`width_phases`], in ascending id order —
/// the per-phase work lists the phased engines execute. Assignment goes
/// through a depth→phase lookup table (phases are contiguous depth
/// bands), so the cost is O(V + E + depths), not O(V × phases).
pub fn phase_members(graph: &Graph, phases: &[Phase]) -> Vec<Vec<NodeId>> {
    let depth = depths(graph);
    let max_depth = phases.last().map(|p| p.last_depth as usize).unwrap_or(0);
    let mut phase_of_depth = vec![usize::MAX; max_depth + 1];
    for (k, p) in phases.iter().enumerate() {
        for d in p.first_depth..=p.last_depth {
            phase_of_depth[d as usize] = k;
        }
    }
    let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); phases.len()];
    for v in 0..graph.len() as NodeId {
        let k = phase_of_depth[depth[v as usize] as usize];
        debug_assert_ne!(k, usize::MAX, "width_phases covers every depth");
        members[k].push(v);
    }
    members
}

/// Lower bound on makespan with unlimited executors: the critical-path
/// length. Used to sanity-check every engine's output.
pub fn critical_path_length(graph: &Graph, durations: &[f64]) -> f64 {
    levels(graph, durations)
        .into_iter()
        .fold(0.0f64, f64::max)
}

/// Lower bound on makespan with `k` executors of fixed speed:
/// `max(cp_length, total_work / k)` — the classic area/chain bound.
pub fn makespan_lower_bound(graph: &Graph, durations: &[f64], k: usize) -> f64 {
    let total: f64 = durations.iter().sum();
    critical_path_length(graph, durations).max(total / k as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    /// chain a(3) -> b(2) -> c(1), plus independent d(4)
    fn sample() -> (Graph, Vec<f64>) {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let x = b.add("b", OpKind::Scalar);
        let y = b.add("c", OpKind::Scalar);
        b.add("d", OpKind::Scalar);
        b.depend(a, x);
        b.depend(x, y);
        (b.build().unwrap(), vec![3.0, 2.0, 1.0, 4.0])
    }

    #[test]
    fn chain_levels() {
        let (g, dur) = sample();
        let l = levels(&g, &dur);
        assert_eq!(l, vec![6.0, 3.0, 1.0, 4.0]);
    }

    #[test]
    fn critical_path_follows_chain() {
        let (g, dur) = sample();
        assert_eq!(critical_path(&g, &dur), vec![0, 1, 2]);
        assert_eq!(critical_path_length(&g, &dur), 6.0);
    }

    #[test]
    fn lower_bound_switches_regime() {
        let (g, dur) = sample();
        // total work 10; with k=1 area bound dominates (10 > 6)
        assert_eq!(makespan_lower_bound(&g, &dur, 1), 10.0);
        // with k=4 the chain dominates
        assert_eq!(makespan_lower_bound(&g, &dur, 4), 6.0);
    }

    #[test]
    fn diamond_takes_longer_branch() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let fast = b.add("fast", OpKind::Scalar);
        let slow = b.add("slow", OpKind::Scalar);
        let d = b.add("d", OpKind::Scalar);
        b.depend(a, fast);
        b.depend(a, slow);
        b.depend(fast, d);
        b.depend(slow, d);
        let g = b.build().unwrap();
        let dur = vec![1.0, 1.0, 10.0, 1.0];
        let l = levels(&g, &dur);
        assert_eq!(l[0], 1.0 + 10.0 + 1.0);
        assert_eq!(critical_path(&g, &dur), vec![0, 2, 3]);
    }

    #[test]
    fn levels_of_single_node() {
        let mut b = GraphBuilder::new();
        b.add("only", OpKind::Scalar);
        let g = b.build().unwrap();
        assert_eq!(levels(&g, &[7.5]), vec![7.5]);
        assert_eq!(critical_path(&g, &[7.5]), vec![0]);
    }

    #[test]
    #[should_panic(expected = "one duration per node")]
    fn wrong_duration_len_panics() {
        let (g, _) = sample();
        levels(&g, &[1.0]);
    }

    /// 1 → {4 wide} → {4 wide} → 1: a narrow head, a wide middle band,
    /// a narrow tail.
    fn fan_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let src = b.add("src", OpKind::Scalar);
        let mut mid2 = Vec::new();
        for i in 0..4 {
            let m1 = b.add(format!("m1_{i}"), OpKind::Scalar);
            b.depend(src, m1);
            let m2 = b.add(format!("m2_{i}"), OpKind::Scalar);
            b.depend(m1, m2);
            mid2.push(m2);
        }
        let sink = b.add("sink", OpKind::Scalar);
        for &m in &mid2 {
            b.depend(m, sink);
        }
        b.build().unwrap()
    }

    #[test]
    fn depths_count_hops_from_sources() {
        let (g, _) = sample();
        // chain a→b→c plus isolated d
        assert_eq!(depths(&g), vec![0, 1, 2, 0]);
        let fan = fan_graph();
        let d = depths(&fan);
        assert_eq!(d[0], 0, "source");
        assert_eq!(*d.iter().max().unwrap(), 3, "sink is 3 hops deep");
        // every edge goes strictly downward in depth
        for v in 0..fan.len() as u32 {
            for &p in fan.preds(v) {
                assert!(d[p as usize] < d[v as usize]);
            }
        }
    }

    #[test]
    fn width_phases_band_consecutive_same_class_depths() {
        let fan = fan_graph();
        // widths per depth: 1, 4, 4, 1 → at threshold 2: narrow|wide|narrow
        let phases = width_phases(&fan, 2);
        assert_eq!(phases.len(), 3);
        assert!(!phases[0].wide && phases[1].wide && !phases[2].wide);
        assert_eq!(phases[0].nodes, 1);
        assert_eq!(phases[1].nodes, 8);
        assert_eq!(phases[1].max_width, 4);
        assert_eq!(phases[2].nodes, 1);
        assert_eq!((phases[1].first_depth, phases[1].last_depth), (1, 2));
        // every node lands in exactly one phase
        assert_eq!(phases.iter().map(|p| p.nodes).sum::<usize>(), fan.len());
        // threshold above the max width ⇒ one all-narrow phase
        let one = width_phases(&fan, 50);
        assert_eq!(one.len(), 1);
        assert!(!one[0].wide);
        assert_eq!(one[0].nodes, fan.len());
        // threshold 1 ⇒ every depth is wide ⇒ one all-wide phase
        let wide = width_phases(&fan, 1);
        assert_eq!(wide.len(), 1);
        assert!(wide[0].wide);
    }

    #[test]
    fn phase_members_partition_nodes_and_respect_dependencies() {
        let fan = fan_graph();
        let phases = width_phases(&fan, 2);
        let members = phase_members(&fan, &phases);
        assert_eq!(members.len(), phases.len());
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, fan.len());
        // phase index of each node
        let mut phase_of = vec![usize::MAX; fan.len()];
        for (k, m) in members.iter().enumerate() {
            assert_eq!(m.len(), phases[k].nodes);
            for &v in m {
                phase_of[v as usize] = k;
            }
        }
        // predecessors never live in a *later* phase
        for v in 0..fan.len() as u32 {
            for &p in fan.preds(v) {
                assert!(phase_of[p as usize] <= phase_of[v as usize]);
            }
        }
    }
}
