//! Critical-path "level" values (§4.3 of the paper).
//!
//! > "…we can derive a level value for each operation, which is defined as
//! > the longest accumulated time from this operation to the end (sink
//! > point) of the computation graph."
//!
//! The scheduler sorts ready operations by decreasing level so the critical
//! path never starves. Levels are computed once per profiling update in
//! reverse topological order, O(V + E).

use super::dag::{Graph, NodeId};

/// Compute level values given per-node estimated durations (µs).
///
/// `level(v) = dur(v) + max(level(s) for s in succs(v))`, 0-max for sinks.
pub fn levels(graph: &Graph, durations: &[f64]) -> Vec<f64> {
    assert_eq!(durations.len(), graph.len(), "one duration per node");
    let order = graph.topo_order();
    let mut level = vec![0.0f64; graph.len()];
    for &v in order.iter().rev() {
        let mut best = 0.0f64;
        for &s in graph.succs(v) {
            best = best.max(level[s as usize]);
        }
        level[v as usize] = durations[v as usize] + best;
    }
    level
}

/// The critical path itself: a source-to-sink node sequence achieving the
/// maximum accumulated duration. Useful for traces and for the §7.4
/// wavefront analysis.
pub fn critical_path(graph: &Graph, durations: &[f64]) -> Vec<NodeId> {
    let level = levels(graph, durations);
    let mut current = (0..graph.len() as NodeId)
        .filter(|&v| graph.in_degree(v) == 0)
        .max_by(|&a, &b| level[a as usize].total_cmp(&level[b as usize]))
        .expect("non-empty graph has a source");
    let mut path = vec![current];
    loop {
        let next = graph
            .succs(current)
            .iter()
            .copied()
            .max_by(|&a, &b| level[a as usize].total_cmp(&level[b as usize]));
        match next {
            Some(n) => {
                path.push(n);
                current = n;
            }
            None => return path,
        }
    }
}

/// Lower bound on makespan with unlimited executors: the critical-path
/// length. Used to sanity-check every engine's output.
pub fn critical_path_length(graph: &Graph, durations: &[f64]) -> f64 {
    levels(graph, durations)
        .into_iter()
        .fold(0.0f64, f64::max)
}

/// Lower bound on makespan with `k` executors of fixed speed:
/// `max(cp_length, total_work / k)` — the classic area/chain bound.
pub fn makespan_lower_bound(graph: &Graph, durations: &[f64], k: usize) -> f64 {
    let total: f64 = durations.iter().sum();
    critical_path_length(graph, durations).max(total / k as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    /// chain a(3) -> b(2) -> c(1), plus independent d(4)
    fn sample() -> (Graph, Vec<f64>) {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let x = b.add("b", OpKind::Scalar);
        let y = b.add("c", OpKind::Scalar);
        b.add("d", OpKind::Scalar);
        b.depend(a, x);
        b.depend(x, y);
        (b.build().unwrap(), vec![3.0, 2.0, 1.0, 4.0])
    }

    #[test]
    fn chain_levels() {
        let (g, dur) = sample();
        let l = levels(&g, &dur);
        assert_eq!(l, vec![6.0, 3.0, 1.0, 4.0]);
    }

    #[test]
    fn critical_path_follows_chain() {
        let (g, dur) = sample();
        assert_eq!(critical_path(&g, &dur), vec![0, 1, 2]);
        assert_eq!(critical_path_length(&g, &dur), 6.0);
    }

    #[test]
    fn lower_bound_switches_regime() {
        let (g, dur) = sample();
        // total work 10; with k=1 area bound dominates (10 > 6)
        assert_eq!(makespan_lower_bound(&g, &dur, 1), 10.0);
        // with k=4 the chain dominates
        assert_eq!(makespan_lower_bound(&g, &dur, 4), 6.0);
    }

    #[test]
    fn diamond_takes_longer_branch() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let fast = b.add("fast", OpKind::Scalar);
        let slow = b.add("slow", OpKind::Scalar);
        let d = b.add("d", OpKind::Scalar);
        b.depend(a, fast);
        b.depend(a, slow);
        b.depend(fast, d);
        b.depend(slow, d);
        let g = b.build().unwrap();
        let dur = vec![1.0, 1.0, 10.0, 1.0];
        let l = levels(&g, &dur);
        assert_eq!(l[0], 1.0 + 10.0 + 1.0);
        assert_eq!(critical_path(&g, &dur), vec![0, 2, 3]);
    }

    #[test]
    fn levels_of_single_node() {
        let mut b = GraphBuilder::new();
        b.add("only", OpKind::Scalar);
        let g = b.build().unwrap();
        assert_eq!(levels(&g, &[7.5]), vec![7.5]);
        assert_eq!(critical_path(&g, &[7.5]), vec![0]);
    }

    #[test]
    #[should_panic(expected = "one duration per node")]
    fn wrong_duration_len_panics() {
        let (g, _) = sample();
        levels(&g, &[1.0]);
    }
}
