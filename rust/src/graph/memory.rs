//! Memory planning (the CGT substrate, §5.1 of the paper).
//!
//! > "Each variable will be assigned a memory location, and optimizations
//! > during compilation allow multiple variables to share the same
//! > location as long as their lifespans do not overlap."
//!
//! Given a graph and an execution order, the planner computes each node
//! output's live range (defined at the producer, dead after its last
//! consumer), then assigns byte offsets with a greedy first-fit over a
//! free-list — the classic linear-scan register-allocation shape. The
//! result reports peak footprint, which is what bounds batch size on the
//! 16 GB MCDRAM (§7.1: batch "to maximally utilize the 16GB MCDRAM").

use super::dag::{Graph, NodeId};

/// One output buffer's plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub node: NodeId,
    pub offset: u64,
    pub size: u64,
    /// Position in the order where the buffer becomes live.
    pub start: usize,
    /// Position after which the buffer is dead (last consumer).
    pub end: usize,
}

/// A complete memory plan.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    pub allocations: Vec<Allocation>,
    /// Arena size = peak concurrent footprint with sharing.
    pub arena_bytes: u64,
    /// Sum of all buffer sizes (the no-sharing baseline).
    pub total_bytes: u64,
}

impl MemoryPlan {
    /// How much sharing saved vs naive per-output allocation.
    pub fn sharing_ratio(&self) -> f64 {
        if self.arena_bytes == 0 {
            1.0
        } else {
            self.total_bytes as f64 / self.arena_bytes as f64
        }
    }

    /// Does the plan fit a memory budget (e.g. 16 GB MCDRAM)?
    pub fn fits(&self, budget_bytes: u64) -> bool {
        self.arena_bytes <= budget_bytes
    }

    /// One-line human-readable summary, shared by `graphi run`, `graphi
    /// stats` and `graphi memplan` so the three surfaces cannot drift.
    pub fn summary_line(&self) -> String {
        render_summary(self.arena_bytes, self.total_bytes, self.sharing_ratio())
    }

    /// Verify the invariant: no two live-range-overlapping allocations
    /// overlap in address space. Used by tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        for (i, a) in self.allocations.iter().enumerate() {
            for b in &self.allocations[i + 1..] {
                let time_overlap = a.start <= b.end && b.start <= a.end;
                let addr_overlap = a.offset < b.offset + b.size && b.offset < a.offset + a.size;
                if time_overlap && addr_overlap && a.size > 0 && b.size > 0 {
                    return Err(format!(
                        "buffers for nodes {} and {} overlap in time and space",
                        a.node, b.node
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Render a plan summary from its three headline numbers — the
/// free-function form exists for callers (e.g. experiment results) that
/// persist the numbers rather than the whole [`MemoryPlan`].
pub fn render_summary(arena_bytes: u64, total_bytes: u64, sharing_ratio: f64) -> String {
    format!(
        "peak footprint {}  no-sharing {}  sharing {:.2}x  fits 16 GB MCDRAM: {}",
        crate::util::fmt_si(arena_bytes as f64),
        crate::util::fmt_si(total_bytes as f64),
        sharing_ratio,
        if arena_bytes <= (16u64 << 30) { "yes" } else { "NO" }
    )
}

/// Simple first-fit free-list allocator over a growable arena.
struct Arena {
    /// Sorted, disjoint free intervals `(offset, size)` inside `high`.
    free: Vec<(u64, u64)>,
    high: u64,
}

impl Arena {
    fn new() -> Arena {
        Arena { free: Vec::new(), high: 0 }
    }

    fn alloc(&mut self, size: u64) -> u64 {
        if size == 0 {
            return 0;
        }
        // first fit in the free list
        for i in 0..self.free.len() {
            let (off, cap) = self.free[i];
            if cap >= size {
                if cap == size {
                    self.free.remove(i);
                } else {
                    self.free[i] = (off + size, cap - size);
                }
                return off;
            }
        }
        // grow
        let off = self.high;
        self.high += size;
        off
    }

    fn release(&mut self, offset: u64, size: u64) {
        if size == 0 {
            return;
        }
        // insert sorted + coalesce neighbours
        let pos = self.free.partition_point(|&(o, _)| o < offset);
        self.free.insert(pos, (offset, size));
        // coalesce right
        if pos + 1 < self.free.len() {
            let (o, s) = self.free[pos];
            let (no, ns) = self.free[pos + 1];
            if o + s == no {
                self.free[pos] = (o, s + ns);
                self.free.remove(pos + 1);
            }
        }
        // coalesce left
        if pos > 0 {
            let (po, ps) = self.free[pos - 1];
            let (o, s) = self.free[pos];
            if po + ps == o {
                self.free[pos - 1] = (po, ps + s);
                self.free.remove(pos);
            }
        }
    }
}

/// Plan memory for `graph` executed in `order` (must be a valid schedule;
/// typically `graph.topo_order()` or an engine's record order). Output
/// buffers are `output_elems × 4` bytes (f32).
pub fn plan(graph: &Graph, order: &[NodeId]) -> MemoryPlan {
    assert_eq!(order.len(), graph.len(), "order must cover the graph");
    debug_assert!(graph.validate_order(order).is_ok());
    let mut position = vec![0usize; graph.len()];
    for (i, &v) in order.iter().enumerate() {
        position[v as usize] = i;
    }
    // last use of each node's output
    let mut last_use = vec![0usize; graph.len()];
    for v in 0..graph.len() as NodeId {
        let mut end = position[v as usize];
        for &s in graph.succs(v) {
            end = end.max(position[s as usize]);
        }
        last_use[v as usize] = end;
    }
    // sweep in execution order: release buffers whose last use has passed,
    // then allocate the new output
    let mut arena = Arena::new();
    let mut allocations: Vec<Allocation> = Vec::with_capacity(graph.len());
    // buffers to release keyed by position: release[i] = node ids whose
    // last use is at position i
    let mut release_at: Vec<Vec<NodeId>> = vec![Vec::new(); order.len()];
    for v in 0..graph.len() as NodeId {
        release_at[last_use[v as usize]].push(v);
    }
    let mut offsets = vec![0u64; graph.len()];
    let mut total_bytes = 0u64;
    for (i, &v) in order.iter().enumerate() {
        let size = graph.node(v).kind.output_elems() * 4;
        total_bytes += size;
        let offset = arena.alloc(size);
        offsets[v as usize] = offset;
        allocations.push(Allocation {
            node: v,
            offset,
            size,
            start: i,
            end: last_use[v as usize],
        });
        // release everything whose last consumer just ran (including
        // self-release for nodes with no consumers)
        for &dead in &release_at[i] {
            let a = &allocations[position[dead as usize].min(allocations.len() - 1)];
            debug_assert_eq!(a.node, dead);
            arena.release(offsets[dead as usize], graph.node(dead).kind.output_elems() * 4);
        }
    }
    let plan = MemoryPlan { allocations, arena_bytes: arena.high, total_bytes };
    debug_assert!(plan.validate().is_ok(), "{:?}", plan.validate());
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::{EwKind, OpKind};
    use crate::graph::GraphBuilder;

    fn ew(n: u64) -> OpKind {
        OpKind::Elementwise { n, arity: 1, kind: EwKind::Arith }
    }

    #[test]
    fn chain_reuses_one_slot_pair() {
        // a -> b -> c -> d, all same size: at any moment only producer +
        // consumer are live ⇒ arena of 2 buffers
        let mut b = GraphBuilder::new();
        let mut prev = b.add("n0", ew(1000));
        for i in 1..6 {
            prev = b.add_after(format!("n{i}"), ew(1000), &[prev]);
        }
        let g = b.build().unwrap();
        let order = g.topo_order();
        let plan = plan(&g, &order);
        plan.validate().unwrap();
        assert_eq!(plan.total_bytes, 6 * 4000);
        assert_eq!(plan.arena_bytes, 2 * 4000, "chain should reuse two slots");
        assert!(plan.sharing_ratio() > 2.9);
    }

    #[test]
    fn diamond_keeps_both_branches_live() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", ew(1000));
        let l = b.add_after("l", ew(1000), &[a]);
        let r = b.add_after("r", ew(1000), &[a]);
        b.add_after("join", ew(1000), &[l, r]);
        let g = b.build().unwrap();
        let plan = plan(&g, &g.topo_order());
        plan.validate().unwrap();
        // at the join: l, r and join's output live simultaneously
        assert!(plan.arena_bytes >= 3 * 4000);
        assert!(plan.arena_bytes <= 4 * 4000);
    }

    #[test]
    fn zero_size_outputs_ok() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        b.add_after("b", OpKind::Scalar, &[a]);
        let g = b.build().unwrap();
        let plan = plan(&g, &g.topo_order());
        plan.validate().unwrap();
        assert!(plan.arena_bytes <= 8);
    }

    #[test]
    fn plan_respects_alternate_valid_orders() {
        // two independent chains interleaved arbitrarily still validate
        let mut b = GraphBuilder::new();
        let a0 = b.add("a0", ew(500));
        let a1 = b.add_after("a1", ew(500), &[a0]);
        let c0 = b.add("c0", ew(500));
        let c1 = b.add_after("c1", ew(500), &[c0]);
        let g = b.build().unwrap();
        let order = vec![a0, c0, a1, c1];
        let plan = plan(&g, &order);
        plan.validate().unwrap();
    }

    #[test]
    fn summary_line_is_shared_and_budget_aware() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", ew(1000));
        b.add_after("b", ew(1000), &[a]);
        let g = b.build().unwrap();
        let p = plan(&g, &g.topo_order());
        let line = p.summary_line();
        assert!(line.contains("peak footprint"), "{line}");
        assert!(line.contains("sharing"), "{line}");
        assert!(line.ends_with("yes"), "{line}");
        assert_eq!(line, render_summary(p.arena_bytes, p.total_bytes, p.sharing_ratio()));
        assert!(render_summary(17 << 30, 17 << 30, 1.0).ends_with("NO"));
    }

    #[test]
    fn arena_free_list_coalesces() {
        let mut a = Arena::new();
        let x = a.alloc(100);
        let y = a.alloc(100);
        let z = a.alloc(100);
        assert_eq!((x, y, z), (0, 100, 200));
        a.release(y, 100);
        a.release(x, 100);
        // coalesced [0,200): a 150-byte alloc must fit at 0
        assert_eq!(a.alloc(150), 0);
    }

    #[test]
    fn models_fit_mcdram() {
        // §7.1: batch sizes chosen to fit the 16 GB MCDRAM
        use crate::models::{self, ModelKind, ModelSize};
        for kind in [ModelKind::Lstm, ModelKind::PathNet, ModelKind::GoogleNet] {
            let g = models::build(kind, ModelSize::Large);
            let p = plan(&g, &g.topo_order());
            assert!(
                p.fits(16 << 30),
                "{:?} large needs {} bytes",
                kind,
                p.arena_bytes
            );
            assert!(p.sharing_ratio() > 1.5, "{kind:?}: sharing ratio {}", p.sharing_ratio());
        }
    }

    #[test]
    fn property_no_live_overlaps_on_random_dags() {
        use crate::util::testkit::{check, DagGen};
        let gen = DagGen { max_nodes: 50, edge_prob: 0.2, wmax: 100.0 };
        check("memory plan validity", &gen, 60, |case| {
            let mut b = GraphBuilder::new();
            for i in 0..case.n {
                b.add(format!("n{i}"), ew(100 + (case.weights[i] * 10.0) as u64));
            }
            for &(s, d) in &case.edges {
                b.depend(s, d);
            }
            let g = b.build().map_err(|e| e.to_string())?;
            let p = plan(&g, &g.topo_order());
            p.validate()?;
            if p.arena_bytes > p.total_bytes {
                return Err("arena larger than no-sharing total".into());
            }
            Ok(())
        });
    }
}
