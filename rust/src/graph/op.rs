//! Operation kinds.
//!
//! Each node of a computation graph carries an [`OpKind`] describing the
//! mathematical operation and its shape. The kind determines the flop and
//! byte volumes the cost model prices, the intra-op scalability class
//! (GEMM vs element-wise vs convolution — Fig 2 of the paper), and whether
//! the op is small enough to run inline on the light-weight executor
//! (§5.2).

/// Element-wise operation flavor; affects per-element cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    /// Plain arithmetic (add/sub/mul) — 1 flop per element per input.
    Arith,
    /// Sigmoid/tanh-style transcendental — several flops per element.
    Transcendental,
    /// ReLU / comparison / select — cheap, branch-free.
    Relu,
    /// The fused LSTM gate update (3 sigmoids, 2 tanhs, 4 muls, adds).
    FusedGates,
    /// Memory copy / transpose-free reshape — bandwidth only.
    Copy,
}

impl EwKind {
    /// Approximate flops per output element (used by the cost model).
    pub fn flops_per_element(self) -> f64 {
        match self {
            EwKind::Arith => 1.0,
            EwKind::Transcendental => 10.0,
            EwKind::Relu => 1.0,
            EwKind::FusedGates => 30.0,
            EwKind::Copy => 0.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EwKind::Arith => "arith",
            EwKind::Transcendental => "transcendental",
            EwKind::Relu => "relu",
            EwKind::FusedGates => "fused_gates",
            EwKind::Copy => "copy",
        }
    }
}

/// A typed operation with shape information.
///
/// All tensors are f32 (4 bytes/element), matching the paper's workloads.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Dense matrix multiply `C[m,n] = A[m,k] · B[k,n]` (MKL GEMM class).
    MatMul { m: u64, k: u64, n: u64 },
    /// 2-D convolution, NCHW, square kernel (LIBXSMM class).
    Conv2d {
        batch: u64,
        h: u64,
        w: u64,
        cin: u64,
        cout: u64,
        kernel: u64,
        stride: u64,
    },
    /// 2-D max/avg pooling.
    Pool2d { batch: u64, h: u64, w: u64, c: u64, window: u64, stride: u64 },
    /// Element-wise map over `n` output elements with `arity` inputs.
    Elementwise { n: u64, arity: u64, kind: EwKind },
    /// Reduction (sum/max) over `n` elements.
    Reduce { n: u64 },
    /// Softmax + cross-entropy over `batch × classes`.
    Softmax { batch: u64, classes: u64 },
    /// Concatenate tensors totalling `n` elements (bandwidth only).
    Concat { n: u64 },
    /// Parameter update `w -= lr·g` over `n` elements.
    SgdUpdate { n: u64 },
    /// Tiny bookkeeping op (scalar arithmetic, shape math, control).
    /// Runs inline on the light-weight executor.
    Scalar,
}

/// Scalability class of an op — which saturation curve from Fig 2 applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    Gemm,
    Conv,
    Elementwise,
    Memory,
    Tiny,
}

impl OpClass {
    /// Every class, in the canonical order used by per-class tables
    /// (width plans, histograms). [`Self::index`] is the position here.
    pub const ALL: [OpClass; 5] =
        [OpClass::Gemm, OpClass::Conv, OpClass::Elementwise, OpClass::Memory, OpClass::Tiny];

    /// Number of classes (`ALL.len()`), for fixed-size per-class arrays.
    pub const COUNT: usize = OpClass::ALL.len();

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Gemm => "gemm",
            OpClass::Conv => "conv",
            OpClass::Elementwise => "elementwise",
            OpClass::Memory => "memory",
            OpClass::Tiny => "tiny",
        }
    }

    /// Position of this class in [`Self::ALL`].
    pub fn index(self) -> usize {
        match self {
            OpClass::Gemm => 0,
            OpClass::Conv => 1,
            OpClass::Elementwise => 2,
            OpClass::Memory => 3,
            OpClass::Tiny => 4,
        }
    }

    /// Inverse of [`Self::name`] (tuning-artifact deserialization).
    pub fn parse(s: &str) -> Option<OpClass> {
        OpClass::ALL.iter().copied().find(|c| c.name() == s)
    }
}

const F32: u64 = 4;

impl OpKind {
    /// Floating-point operations performed.
    pub fn flops(&self) -> f64 {
        match *self {
            OpKind::MatMul { m, k, n } => 2.0 * m as f64 * k as f64 * n as f64,
            OpKind::Conv2d { batch, h, w, cin, cout, kernel, stride } => {
                let (oh, ow) = conv_out(h, w, kernel, stride);
                2.0 * batch as f64
                    * oh as f64
                    * ow as f64
                    * cout as f64
                    * cin as f64
                    * (kernel * kernel) as f64
            }
            OpKind::Pool2d { batch, h, w, c, window, stride } => {
                let (oh, ow) = conv_out(h, w, window, stride);
                batch as f64 * oh as f64 * ow as f64 * c as f64 * (window * window) as f64
            }
            OpKind::Elementwise { n, arity, kind } => {
                n as f64 * (kind.flops_per_element() + (arity.saturating_sub(1)) as f64)
            }
            OpKind::Reduce { n } => n as f64,
            OpKind::Softmax { batch, classes } => 5.0 * batch as f64 * classes as f64,
            OpKind::Concat { .. } => 0.0,
            OpKind::SgdUpdate { n } => 2.0 * n as f64,
            OpKind::Scalar => 1.0,
        }
    }

    /// Bytes moved to/from memory (reads + writes, f32).
    pub fn bytes(&self) -> f64 {
        let elems: f64 = match *self {
            OpKind::MatMul { m, k, n } => (m * k + k * n + m * n) as f64,
            OpKind::Conv2d { batch, h, w, cin, cout, kernel, stride } => {
                let (oh, ow) = conv_out(h, w, kernel, stride);
                (batch * h * w * cin              // input
                    + cout * cin * kernel * kernel // weights
                    + batch * oh * ow * cout) as f64 // output
            }
            OpKind::Pool2d { batch, h, w, c, window, stride } => {
                let (oh, ow) = conv_out(h, w, window, stride);
                (batch * h * w * c + batch * oh * ow * c) as f64
            }
            OpKind::Elementwise { n, arity, .. } => (n * (arity + 1)) as f64,
            OpKind::Reduce { n } => n as f64 + 1.0,
            OpKind::Softmax { batch, classes } => 2.0 * (batch * classes) as f64,
            OpKind::Concat { n } => 2.0 * n as f64,
            OpKind::SgdUpdate { n } => 3.0 * n as f64,
            OpKind::Scalar => 2.0,
        };
        elems * F32 as f64
    }

    /// Number of output elements (for buffer sizing / stream stores).
    pub fn output_elems(&self) -> u64 {
        match *self {
            OpKind::MatMul { m, n, .. } => m * n,
            OpKind::Conv2d { batch, h, w, cout, kernel, stride, .. } => {
                let (oh, ow) = conv_out(h, w, kernel, stride);
                batch * oh * ow * cout
            }
            OpKind::Pool2d { batch, h, w, c, window, stride } => {
                let (oh, ow) = conv_out(h, w, window, stride);
                batch * oh * ow * c
            }
            OpKind::Elementwise { n, .. } => n,
            OpKind::Reduce { .. } => 1,
            OpKind::Softmax { batch, classes } => batch * classes,
            OpKind::Concat { n } => n,
            OpKind::SgdUpdate { n } => n,
            OpKind::Scalar => 1,
        }
    }

    /// Scalability class — selects the Fig 2 saturation curve.
    pub fn class(&self) -> OpClass {
        match self {
            OpKind::MatMul { .. } => OpClass::Gemm,
            OpKind::Conv2d { .. } => OpClass::Conv,
            OpKind::Pool2d { .. }
            | OpKind::Elementwise { .. }
            | OpKind::Reduce { .. }
            | OpKind::Softmax { .. }
            | OpKind::SgdUpdate { .. } => OpClass::Elementwise,
            OpKind::Concat { .. } => OpClass::Memory,
            OpKind::Scalar => OpClass::Tiny,
        }
    }

    /// Ops below this flop count are "small" and run inline on the
    /// light-weight executor instead of being scheduled (§5.2).
    pub fn is_tiny(&self) -> bool {
        matches!(self, OpKind::Scalar) || self.flops() < 2_000.0
    }

    /// Arithmetic intensity, flops per byte.
    pub fn intensity(&self) -> f64 {
        let b = self.bytes();
        if b == 0.0 {
            0.0
        } else {
            self.flops() / b
        }
    }

    /// Short mnemonic used in traces and DOT output.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::MatMul { .. } => "gemm",
            OpKind::Conv2d { .. } => "conv",
            OpKind::Pool2d { .. } => "pool",
            OpKind::Elementwise { .. } => "ew",
            OpKind::Reduce { .. } => "reduce",
            OpKind::Softmax { .. } => "softmax",
            OpKind::Concat { .. } => "concat",
            OpKind::SgdUpdate { .. } => "sgd",
            OpKind::Scalar => "scalar",
        }
    }
}

fn conv_out(h: u64, w: u64, _kernel: u64, stride: u64) -> (u64, u64) {
    // "same"-ish padding: ceil(h/stride); keeps shape math simple and is
    // what the paper's workloads (3×3 stride-1, pool 2×2 stride-2) need.
    (h.div_ceil(stride), w.div_ceil(stride))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_flops_bytes() {
        // The paper's microbenchmark GEMM: [64,512] x [512,512]
        let op = OpKind::MatMul { m: 64, k: 512, n: 512 };
        assert_eq!(op.flops(), 2.0 * 64.0 * 512.0 * 512.0);
        let elems = 64 * 512 + 512 * 512 + 64 * 512;
        assert_eq!(op.bytes(), (elems * 4) as f64);
        assert_eq!(op.class(), OpClass::Gemm);
        assert!(!op.is_tiny());
    }

    #[test]
    fn elementwise_microbenchmark_shape() {
        // The paper's 32768-pair element-wise multiply
        let op = OpKind::Elementwise { n: 32_768, arity: 2, kind: EwKind::Arith };
        assert_eq!(op.flops(), 32_768.0 * 2.0);
        assert_eq!(op.bytes(), (32_768 * 3 * 4) as f64);
        assert_eq!(op.class(), OpClass::Elementwise);
    }

    #[test]
    fn conv_shapes() {
        let op = OpKind::Conv2d { batch: 64, h: 32, w: 32, cin: 16, cout: 16, kernel: 3, stride: 1 };
        // out 32x32
        assert_eq!(op.output_elems(), 64 * 32 * 32 * 16);
        assert_eq!(op.flops(), 2.0 * 64.0 * 32.0 * 32.0 * 16.0 * 16.0 * 9.0);
        assert_eq!(op.class(), OpClass::Conv);
    }

    #[test]
    fn pool_halves_spatial() {
        let op = OpKind::Pool2d { batch: 1, h: 32, w: 32, c: 8, window: 2, stride: 2 };
        assert_eq!(op.output_elems(), 16 * 16 * 8);
    }

    #[test]
    fn scalar_is_tiny() {
        assert!(OpKind::Scalar.is_tiny());
        assert!(OpKind::Elementwise { n: 10, arity: 1, kind: EwKind::Arith }.is_tiny());
        assert!(!OpKind::Elementwise { n: 100_000, arity: 1, kind: EwKind::Arith }.is_tiny());
    }

    #[test]
    fn intensity_of_gemm_exceeds_elementwise() {
        let gemm = OpKind::MatMul { m: 512, k: 512, n: 512 };
        let ew = OpKind::Elementwise { n: 512 * 512, arity: 2, kind: EwKind::Arith };
        assert!(gemm.intensity() > 10.0 * ew.intensity());
    }

    #[test]
    fn fused_gates_cost_dominates_arith() {
        let a = EwKind::FusedGates.flops_per_element();
        let b = EwKind::Arith.flops_per_element();
        assert!(a > b);
    }

    #[test]
    fn concat_is_memory_class() {
        let op = OpKind::Concat { n: 1000 };
        assert_eq!(op.class(), OpClass::Memory);
        assert_eq!(op.flops(), 0.0);
        assert!(op.bytes() > 0.0);
    }

    #[test]
    fn mnemonics_unique_enough() {
        let ops = [
            OpKind::MatMul { m: 1, k: 1, n: 1 }.mnemonic(),
            OpKind::Scalar.mnemonic(),
            OpKind::Concat { n: 1 }.mnemonic(),
        ];
        assert_eq!(ops, ["gemm", "scalar", "concat"]);
    }

    #[test]
    fn op_class_table_is_consistent() {
        assert_eq!(OpClass::COUNT, OpClass::ALL.len());
        for (i, c) in OpClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i, "index must match ALL position");
            assert_eq!(OpClass::parse(c.name()), Some(*c), "parse inverts name");
        }
        assert_eq!(OpClass::parse("no-such-class"), None);
    }

    #[test]
    fn strided_conv_output() {
        let op = OpKind::Conv2d { batch: 1, h: 33, w: 33, cin: 1, cout: 1, kernel: 3, stride: 2 };
        assert_eq!(op.output_elems(), 17 * 17);
    }
}
