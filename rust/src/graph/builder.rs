//! Mutable graph construction.
//!
//! The model compilers in [`crate::models`] use this API. A builder is
//! append-only: `add` returns a [`NodeId`], `depend(src, dst)` records that
//! `dst` consumes `src`'s output. `build()` validates (no self-edges, no
//! cycles) and freezes into the CSR [`Graph`].

use super::dag::{Graph, GraphError, Node, NodeId};
use super::op::OpKind;

/// Append-only builder for [`Graph`].
#[derive(Debug, Default)]
pub struct GraphBuilder {
    nodes: Vec<Node>,
    edges: Vec<(NodeId, NodeId)>,
}

impl GraphBuilder {
    pub fn new() -> GraphBuilder {
        GraphBuilder::default()
    }

    /// Add an operation; returns its id.
    pub fn add(&mut self, name: impl Into<String>, kind: OpKind) -> NodeId {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(Node { id, name: name.into(), kind });
        id
    }

    /// Add an operation that depends on all of `deps`.
    pub fn add_after(&mut self, name: impl Into<String>, kind: OpKind, deps: &[NodeId]) -> NodeId {
        let id = self.add(name, kind);
        for &d in deps {
            self.depend(d, id);
        }
        id
    }

    /// Record that `dst` depends on `src`.
    pub fn depend(&mut self, src: NodeId, dst: NodeId) {
        self.edges.push((src, dst));
    }

    /// Current number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Validate and freeze.
    pub fn build(self) -> Result<Graph, GraphError> {
        Graph::freeze(self.nodes, self.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_after_wires_all_deps() {
        let mut b = GraphBuilder::new();
        let x = b.add("x", OpKind::Scalar);
        let y = b.add("y", OpKind::Scalar);
        let z = b.add_after("z", OpKind::Scalar, &[x, y]);
        let g = b.build().unwrap();
        assert_eq!(g.preds(z), &[x, y]);
    }

    #[test]
    fn ids_are_sequential() {
        let mut b = GraphBuilder::new();
        assert_eq!(b.add("a", OpKind::Scalar), 0);
        assert_eq!(b.add("b", OpKind::Scalar), 1);
        assert_eq!(b.len(), 2);
    }
}
