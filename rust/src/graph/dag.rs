//! The frozen computation graph.
//!
//! Built once by [`crate::graph::GraphBuilder`], then immutable. Adjacency
//! is stored in CSR form (offset + flat neighbor arrays) in both
//! directions, so the scheduler's hot loop — "which ops did completing `p`
//! trigger?" — is a contiguous slice walk with no allocation.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};

use super::op::OpKind;

/// Node index into [`Graph::nodes`].
pub type NodeId = u32;

/// One operation in the graph.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
}

/// Graph construction / validation errors.
#[derive(Debug, PartialEq)]
pub enum GraphError {
    UnknownNode(NodeId),
    SelfEdge(NodeId),
    Cycle(NodeId, String),
    Empty,
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::UnknownNode(n) => write!(f, "edge references unknown node {n}"),
            GraphError::SelfEdge(n) => write!(f, "self-dependency on node {n}"),
            GraphError::Cycle(n, name) => {
                write!(f, "graph contains a cycle through node {n} ({name})")
            }
            GraphError::Empty => write!(f, "graph is empty"),
        }
    }
}

impl std::error::Error for GraphError {}

/// An immutable DAG of operations.
#[derive(Debug, Clone)]
pub struct Graph {
    nodes: Vec<Node>,
    // CSR successors
    succ_offsets: Vec<u32>,
    succ_list: Vec<NodeId>,
    // CSR predecessors
    pred_offsets: Vec<u32>,
    pred_list: Vec<NodeId>,
}

impl Graph {
    /// Validate and freeze. `edges` are `(src, dst)` dependency pairs
    /// (dst depends on src); duplicates are coalesced.
    pub(super) fn freeze(nodes: Vec<Node>, mut edges: Vec<(NodeId, NodeId)>) -> Result<Graph, GraphError> {
        if nodes.is_empty() {
            return Err(GraphError::Empty);
        }
        let n = nodes.len() as u32;
        for &(a, b) in &edges {
            if a >= n {
                return Err(GraphError::UnknownNode(a));
            }
            if b >= n {
                return Err(GraphError::UnknownNode(b));
            }
            if a == b {
                return Err(GraphError::SelfEdge(a));
            }
        }
        edges.sort_unstable();
        edges.dedup();

        let mut succ_offsets = vec![0u32; n as usize + 1];
        for &(a, _) in &edges {
            succ_offsets[a as usize + 1] += 1;
        }
        for i in 0..n as usize {
            succ_offsets[i + 1] += succ_offsets[i];
        }
        let mut succ_list = vec![0 as NodeId; edges.len()];
        {
            let mut cursor = succ_offsets.clone();
            for &(a, b) in &edges {
                succ_list[cursor[a as usize] as usize] = b;
                cursor[a as usize] += 1;
            }
        }

        let mut pred_offsets = vec![0u32; n as usize + 1];
        for &(_, b) in &edges {
            pred_offsets[b as usize + 1] += 1;
        }
        for i in 0..n as usize {
            pred_offsets[i + 1] += pred_offsets[i];
        }
        let mut pred_list = vec![0 as NodeId; edges.len()];
        {
            let mut cursor = pred_offsets.clone();
            for &(a, b) in &edges {
                pred_list[cursor[b as usize] as usize] = a;
                cursor[b as usize] += 1;
            }
        }

        let g = Graph { nodes, succ_offsets, succ_list, pred_offsets, pred_list };
        // cycle check via Kahn: if topo order is shorter than n, a cycle exists
        let order = g.topo_order_internal();
        if order.len() != g.len() {
            let in_cycle = g.find_cycle_node(&order);
            let name = g.nodes[in_cycle as usize].name.clone();
            return Err(GraphError::Cycle(in_cycle, name));
        }
        Ok(g)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn num_edges(&self) -> usize {
        self.succ_list.len()
    }

    /// Operations depending on `id` (out-edges).
    pub fn succs(&self, id: NodeId) -> &[NodeId] {
        let (a, b) = (
            self.succ_offsets[id as usize] as usize,
            self.succ_offsets[id as usize + 1] as usize,
        );
        &self.succ_list[a..b]
    }

    /// Operations `id` depends on (in-edges).
    pub fn preds(&self, id: NodeId) -> &[NodeId] {
        let (a, b) = (
            self.pred_offsets[id as usize] as usize,
            self.pred_offsets[id as usize + 1] as usize,
        );
        &self.pred_list[a..b]
    }

    pub fn in_degree(&self, id: NodeId) -> usize {
        self.preds(id).len()
    }

    pub fn out_degree(&self, id: NodeId) -> usize {
        self.succs(id).len()
    }

    /// Nodes with no dependencies.
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.len() as NodeId).filter(|&v| self.in_degree(v) == 0).collect()
    }

    /// Nodes nothing depends on.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.len() as NodeId).filter(|&v| self.out_degree(v) == 0).collect()
    }

    /// A topological order (Kahn's algorithm, deterministic: FIFO by id).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let order = self.topo_order_internal();
        debug_assert_eq!(order.len(), self.len(), "graph validated acyclic at freeze");
        order
    }

    fn topo_order_internal(&self) -> Vec<NodeId> {
        let n = self.len();
        let mut indegree: Vec<u32> = (0..n as NodeId).map(|v| self.in_degree(v) as u32).collect();
        let mut queue: std::collections::VecDeque<NodeId> = (0..n as NodeId)
            .filter(|&v| indegree[v as usize] == 0)
            .collect();
        let mut order = Vec::with_capacity(n);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &s in self.succs(v) {
                indegree[s as usize] -= 1;
                if indegree[s as usize] == 0 {
                    queue.push_back(s);
                }
            }
        }
        order
    }

    fn find_cycle_node(&self, topo: &[NodeId]) -> NodeId {
        let mut seen = vec![false; self.len()];
        for &v in topo {
            seen[v as usize] = true;
        }
        (0..self.len() as NodeId)
            .find(|&v| !seen[v as usize])
            .expect("cycle node must exist when topo order is incomplete")
    }

    /// Verify an execution order respects all dependencies. Used by tests
    /// and by the engines' self-checks.
    pub fn validate_order(&self, order: &[NodeId]) -> Result<(), String> {
        if order.len() != self.len() {
            return Err(format!("order has {} nodes, graph has {}", order.len(), self.len()));
        }
        let mut position = vec![usize::MAX; self.len()];
        for (i, &v) in order.iter().enumerate() {
            if (v as usize) >= self.len() {
                return Err(format!("unknown node {v} in order"));
            }
            if position[v as usize] != usize::MAX {
                return Err(format!("node {v} appears twice"));
            }
            position[v as usize] = i;
        }
        for v in 0..self.len() as NodeId {
            for &p in self.preds(v) {
                if position[p as usize] >= position[v as usize] {
                    return Err(format!(
                        "dependency violated: {} must precede {}",
                        self.nodes[p as usize].name, self.nodes[v as usize].name
                    ));
                }
            }
        }
        Ok(())
    }

    /// Like [`validate_order`](Self::validate_order) for a **partial**
    /// execution: nodes must be distinct, each executed node must come
    /// after all of its predecessors, and no executed node may depend on
    /// a node that never ran. This is the shape a fault-truncated trace
    /// must have — a dependency-closed prefix of some full valid order.
    pub fn validate_order_prefix(&self, order: &[NodeId]) -> Result<(), String> {
        let mut position = vec![usize::MAX; self.len()];
        for (i, &v) in order.iter().enumerate() {
            if (v as usize) >= self.len() {
                return Err(format!("unknown node {v} in order"));
            }
            if position[v as usize] != usize::MAX {
                return Err(format!("node {v} appears twice"));
            }
            position[v as usize] = i;
        }
        for &v in order {
            for &p in self.preds(v) {
                if position[p as usize] == usize::MAX {
                    return Err(format!(
                        "dependency violated: {} ran but its dependency {} never did",
                        self.nodes[v as usize].name, self.nodes[p as usize].name
                    ));
                }
                if position[p as usize] >= position[v as usize] {
                    return Err(format!(
                        "dependency violated: {} must precede {}",
                        self.nodes[p as usize].name, self.nodes[v as usize].name
                    ));
                }
            }
        }
        Ok(())
    }

    /// The subgraph induced by `keep`: those nodes (re-numbered
    /// `0..keep.len()` in `keep` order) plus every edge whose endpoints
    /// are both kept. Returns the subgraph and the sub→orig id map (which
    /// is `keep` itself). The phased dispatch runtime executes each width
    /// phase as an induced subgraph — cross-phase edges are dropped
    /// because their sources have already executed when the phase starts.
    ///
    /// `keep` must be non-empty and duplicate-free.
    pub fn induced_subgraph(&self, keep: &[NodeId]) -> (Graph, Vec<NodeId>) {
        let mut orig_to_sub = vec![NodeId::MAX; self.len()];
        let mut builder = super::builder::GraphBuilder::new();
        for &v in keep {
            debug_assert_eq!(orig_to_sub[v as usize], NodeId::MAX, "duplicate node {v} in keep");
            let n = self.node(v);
            orig_to_sub[v as usize] = builder.add(n.name.clone(), n.kind.clone());
        }
        for &v in keep {
            for &s in self.succs(v) {
                if orig_to_sub[s as usize] != NodeId::MAX {
                    builder.depend(orig_to_sub[v as usize], orig_to_sub[s as usize]);
                }
            }
        }
        let sub = builder.build().expect("induced subgraph of a DAG stays a non-empty DAG");
        (sub, keep.to_vec())
    }

    /// The disjoint union of several graphs: every input graph's nodes,
    /// renumbered consecutively in input order (names prefixed `s<i>/`),
    /// with each graph's edges and **no** edges between graphs. Returns
    /// the union plus the origin map `union id → (graph index, local id)`.
    ///
    /// This is the serve-mode simulator substrate
    /// ([`crate::engine::GraphiEngine::run_concurrent`]): N independent
    /// DAGs on one virtual fleet are exactly one union DAG, and because
    /// the components are independent, critical-path levels computed on
    /// the union equal each graph's own levels — so cross-session
    /// CP-first ordering falls out of the ordinary level comparison.
    pub fn disjoint_union(graphs: &[&Graph]) -> (Graph, Vec<(usize, NodeId)>) {
        assert!(!graphs.is_empty(), "disjoint union of zero graphs");
        let total: usize = graphs.iter().map(|g| g.len()).sum();
        let mut nodes = Vec::with_capacity(total);
        let mut edges = Vec::new();
        let mut origin = Vec::with_capacity(total);
        let mut offset: NodeId = 0;
        for (gi, g) in graphs.iter().enumerate() {
            for n in g.nodes() {
                nodes.push(Node {
                    id: offset + n.id,
                    name: format!("s{gi}/{}", n.name),
                    kind: n.kind.clone(),
                });
                origin.push((gi, n.id));
            }
            for v in 0..g.len() as NodeId {
                for &s in g.succs(v) {
                    edges.push((offset + v, offset + s));
                }
            }
            offset += g.len() as NodeId;
        }
        let union = Graph::freeze(nodes, edges).expect("union of DAGs is a non-empty DAG");
        (union, origin)
    }

    /// Total flops over all nodes.
    pub fn total_flops(&self) -> f64 {
        self.nodes.iter().map(|n| n.kind.flops()).sum()
    }

    /// Total bytes over all nodes.
    pub fn total_bytes(&self) -> f64 {
        self.nodes.iter().map(|n| n.kind.bytes()).sum()
    }
}

/// Shared atomic remaining-dependency counters over the graph's CSR
/// successor layout — the decentralized-dispatch core.
///
/// Where [`crate::engine::ready::DepTracker`] is owned by a single
/// scheduler thread, this tracker is shared by every executor: the thread
/// that finishes op `n` walks `graph.succs(n)` (one contiguous CSR slice)
/// and `fetch_sub`s each successor's counter, taking ownership of any
/// successor it decrements to zero. Exactly one thread observes each
/// counter hit zero, so each op is enqueued exactly once with no
/// coordinator round-trip.
///
/// Quiescence is detected the same way: the thread whose completion
/// decrements the remaining-op count to zero is the one that ends the run.
#[derive(Debug)]
pub struct AtomicDepTracker {
    remaining_deps: Box<[AtomicU32]>,
    remaining_ops: AtomicUsize,
    /// Cancellation latch: once set, [`complete`](Self::complete) stops
    /// decrementing and never readies another successor, so a session that
    /// faulted mid-flight can abandon its remaining ops without the
    /// counters ever underflowing under a racing completion.
    cancelled: AtomicBool,
}

impl AtomicDepTracker {
    pub fn new(graph: &Graph) -> AtomicDepTracker {
        let remaining_deps: Box<[AtomicU32]> = (0..graph.len() as NodeId)
            .map(|v| AtomicU32::new(graph.in_degree(v) as u32))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        AtomicDepTracker {
            remaining_deps,
            remaining_ops: AtomicUsize::new(graph.len()),
            cancelled: AtomicBool::new(false),
        }
    }

    /// Mark `node` executed; invoke `on_ready` for each successor this
    /// call decremented to zero (the caller now owns those ops). Returns
    /// `true` iff `node` was the final unexecuted op of the graph — the
    /// caller that sees `true` is responsible for signalling shutdown.
    ///
    /// `AcqRel` on both counters makes every predecessor's work
    /// happen-before the `on_ready` (and the `true` return) that its final
    /// decrement enables.
    pub fn complete(
        &self,
        graph: &Graph,
        node: NodeId,
        mut on_ready: impl FnMut(NodeId),
    ) -> bool {
        if self.cancelled.load(Ordering::Acquire) {
            // A racing completion may still land after cancel() (its op was
            // already executing when the session faulted). Dropping it here
            // keeps the counters exact for the ops that actually completed
            // and guarantees no new successor ever becomes ready.
            return false;
        }
        for &s in graph.succs(node) {
            let prev = self.remaining_deps[s as usize].fetch_sub(1, Ordering::AcqRel);
            debug_assert!(prev > 0, "double trigger of node {s}");
            if prev == 1 {
                on_ready(s);
            }
        }
        let prev_ops = self.remaining_ops.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev_ops > 0, "more completions than ops");
        prev_ops == 1
    }

    /// Abandon the remaining ops: no further [`complete`](Self::complete)
    /// call will decrement a counter or ready a successor. Returns the
    /// number of ops that had not completed when the latch flipped (racy
    /// by nature — completions in flight at the instant of cancellation
    /// may or may not be counted). Idempotent.
    pub fn cancel(&self) -> usize {
        self.cancelled.store(true, Ordering::Release);
        self.remaining_ops.load(Ordering::Acquire)
    }

    /// Has [`cancel`](Self::cancel) latched?
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Ops not yet completed (racy under concurrency; exact once quiesced).
    pub fn remaining(&self) -> usize {
        self.remaining_ops.load(Ordering::Acquire)
    }

    /// Quiesced (every op completed) *or* cancelled — either way, no
    /// further completion will ever be the final one.
    pub fn is_done(&self) -> bool {
        self.remaining() == 0 || self.is_cancelled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::graph::op::OpKind;

    fn diamond() -> Graph {
        // a -> b, a -> c, b -> d, c -> d
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let x = b.add("b", OpKind::Scalar);
        let y = b.add("c", OpKind::Scalar);
        let d = b.add("d", OpKind::Scalar);
        b.depend(a, x);
        b.depend(a, y);
        b.depend(x, d);
        b.depend(y, d);
        b.build().unwrap()
    }

    #[test]
    fn csr_adjacency() {
        let g = diamond();
        assert_eq!(g.succs(0), &[1, 2]);
        assert_eq!(g.preds(3), &[1, 2]);
        assert_eq!(g.in_degree(0), 0);
        assert_eq!(g.out_degree(3), 0);
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    fn topo_order_valid() {
        let g = diamond();
        let order = g.topo_order();
        g.validate_order(&order).unwrap();
        assert_eq!(order[0], 0);
        assert_eq!(order[3], 3);
    }

    #[test]
    fn cycle_detected() {
        let mut b = GraphBuilder::new();
        let x = b.add("x", OpKind::Scalar);
        let y = b.add("y", OpKind::Scalar);
        b.depend(x, y);
        b.depend(y, x);
        match b.build() {
            Err(GraphError::Cycle(_, _)) => {}
            other => panic!("expected cycle, got {other:?}"),
        }
    }

    #[test]
    fn self_edge_rejected() {
        let mut b = GraphBuilder::new();
        let x = b.add("x", OpKind::Scalar);
        b.depend(x, x);
        assert_eq!(b.build().unwrap_err(), GraphError::SelfEdge(0));
    }

    #[test]
    fn empty_rejected() {
        assert_eq!(GraphBuilder::new().build().unwrap_err(), GraphError::Empty);
    }

    #[test]
    fn duplicate_edges_coalesced() {
        let mut b = GraphBuilder::new();
        let x = b.add("x", OpKind::Scalar);
        let y = b.add("y", OpKind::Scalar);
        b.depend(x, y);
        b.depend(x, y);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.in_degree(y), 1);
    }

    #[test]
    fn validate_order_catches_violation() {
        let g = diamond();
        assert!(g.validate_order(&[3, 1, 2, 0]).is_err());
        assert!(g.validate_order(&[0, 1, 2]).is_err()); // wrong length
        assert!(g.validate_order(&[0, 1, 1, 2]).is_err()); // dup
    }

    #[test]
    fn atomic_dep_tracker_triggers_once_and_detects_quiescence() {
        let g = diamond();
        let t = AtomicDepTracker::new(&g);
        assert_eq!(t.remaining(), 4);
        let mut fired = Vec::new();
        assert!(!t.complete(&g, 0, |n| fired.push(n)));
        assert_eq!(fired, vec![1, 2], "sources' successors trigger immediately");
        fired.clear();
        assert!(!t.complete(&g, 1, |n| fired.push(n)));
        assert!(fired.is_empty(), "d still blocked on c");
        assert!(!t.complete(&g, 2, |n| fired.push(n)));
        assert_eq!(fired, vec![3]);
        assert!(t.complete(&g, 3, |_| {}), "final op must report quiescence");
        assert!(t.is_done());
    }

    #[test]
    fn atomic_dep_tracker_cancel_abandons_remaining_ops() {
        let g = diamond();
        let t = AtomicDepTracker::new(&g);
        assert!(!t.complete(&g, 0, |_| {}));
        let left = t.cancel();
        assert_eq!(left, 3, "three ops were outstanding at cancellation");
        assert!(t.is_cancelled());
        assert!(t.is_done(), "cancelled counts as done for quiescence checks");
        // a racing completion that was already executing lands harmlessly:
        // no successor readies, no final-op signal, no counter underflow
        let mut fired = Vec::new();
        assert!(!t.complete(&g, 1, |n| fired.push(n)));
        assert!(!t.complete(&g, 2, |n| fired.push(n)));
        assert!(fired.is_empty(), "cancelled tracker must never ready a successor");
        assert_eq!(t.remaining(), 3, "post-cancel completions do not decrement");
        assert_eq!(t.cancel(), 3, "cancel is idempotent");
    }

    #[test]
    fn atomic_dep_tracker_exactly_once_under_threads() {
        // wide fan-in: 32 predecessors of one sink, completed from 4
        // threads — the sink must trigger exactly once, and exactly one
        // completion must observe quiescence
        let mut b = GraphBuilder::new();
        let preds: Vec<NodeId> = (0..32).map(|i| b.add(format!("p{i}"), OpKind::Scalar)).collect();
        let sink = b.add_after("sink", OpKind::Scalar, &preds);
        let g = b.build().unwrap();
        let t = AtomicDepTracker::new(&g);
        let triggered = std::sync::atomic::AtomicU32::new(0);
        let finals = std::sync::atomic::AtomicU32::new(0);
        std::thread::scope(|scope| {
            for chunk in preds.chunks(8) {
                let (t, g, triggered, finals) = (&t, &g, &triggered, &finals);
                scope.spawn(move || {
                    for &p in chunk {
                        let mut hit = None;
                        if t.complete(g, p, |n| hit = Some(n)) {
                            finals.fetch_add(1, Ordering::SeqCst);
                        }
                        if let Some(n) = hit {
                            assert_eq!(n, sink);
                            triggered.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                });
            }
        });
        assert_eq!(triggered.load(Ordering::SeqCst), 1, "sink triggered exactly once");
        assert_eq!(finals.load(Ordering::SeqCst), 0, "sink itself not yet completed");
        assert!(t.complete(&g, sink, |_| panic!("sink has no successors")));
    }

    #[test]
    fn induced_subgraph_keeps_internal_edges_and_maps_ids() {
        let g = diamond();
        // keep the middle band {b, c} — no internal edges survive
        let (band, map) = g.induced_subgraph(&[1, 2]);
        assert_eq!(band.len(), 2);
        assert_eq!(band.num_edges(), 0);
        assert_eq!(map, vec![1, 2]);
        assert_eq!(band.node(0).name, "b");
        // keep {a, b, d}: a→b and b→d survive, the a→c→d path is dropped
        let (sub, map) = g.induced_subgraph(&[0, 1, 3]);
        assert_eq!(map, vec![0, 1, 3]);
        assert_eq!(sub.num_edges(), 2);
        assert_eq!(sub.succs(0), &[1]);
        assert_eq!(sub.succs(1), &[2]);
        assert_eq!(sub.node(2).name, "d");
        // whole graph round-trips
        let (whole, _) = g.induced_subgraph(&[0, 1, 2, 3]);
        assert_eq!(whole.num_edges(), g.num_edges());
        assert_eq!(whole.topo_order().len(), 4);
    }

    #[test]
    fn disjoint_union_concatenates_without_cross_edges() {
        let a = diamond();
        let mut b = GraphBuilder::new();
        let x = b.add("x", OpKind::Scalar);
        let y = b.add("y", OpKind::Scalar);
        b.depend(x, y);
        let chain = b.build().unwrap();
        let (union, origin) = Graph::disjoint_union(&[&a, &chain]);
        assert_eq!(union.len(), 6);
        assert_eq!(union.num_edges(), a.num_edges() + chain.num_edges());
        assert_eq!(origin[0], (0, 0));
        assert_eq!(origin[4], (1, 0));
        assert_eq!(origin[5], (1, 1));
        assert_eq!(union.node(4).name, "s1/x");
        // component structure preserved, no cross edges
        assert_eq!(union.succs(0), &[1, 2]);
        assert_eq!(union.succs(4), &[5]);
        assert_eq!(union.preds(4), &[] as &[NodeId]);
        assert_eq!(union.sources(), vec![0, 4]);
        union.validate_order(&union.topo_order()).unwrap();
        // independent components ⇒ per-component levels survive the union
        let union_levels = crate::graph::levels(&union, &vec![1.0; union.len()]);
        let a_levels = crate::graph::levels(&a, &vec![1.0; a.len()]);
        for v in 0..a.len() {
            assert_eq!(union_levels[v], a_levels[v]);
        }
    }

    /// Serve-mode batching unions the *same* model graph k times, and
    /// user-authored node names may themselves look like `s0/...` — the
    /// `s<gi>/` prefix must still keep every union name unique and the
    /// origin map must round-trip exactly (trace splitting relies on it).
    #[test]
    fn disjoint_union_names_stay_unique_under_adversarial_inputs() {
        use std::collections::HashSet;
        // adversarial: nodes pre-named with union-style prefixes
        let mut b = GraphBuilder::new();
        let n0 = b.add("s0/op", OpKind::Scalar);
        let n1 = b.add("s1/op", OpKind::Scalar);
        b.depend(n0, n1);
        let tricky = b.build().unwrap();
        // homogeneous 3-way batch of one graph — the serve batcher's shape
        let (union, origin) = Graph::disjoint_union(&[&tricky, &tricky, &tricky]);
        assert_eq!(union.len(), 3 * tricky.len());
        let names: HashSet<&str> = union.nodes().iter().map(|n| n.name.as_str()).collect();
        assert_eq!(names.len(), union.len(), "duplicate node names in the union");
        // origin round-trip: every union name is exactly s<gi>/<local name>
        for u in 0..union.len() {
            let (gi, local) = origin[u];
            assert_eq!(union.node(u as NodeId).name, format!("s{gi}/{}", tricky.node(local).name));
            // component slices are contiguous: union id ↔ (gi, local)
            assert_eq!(u, gi * tricky.len() + local as usize);
        }
    }

    #[test]
    fn disconnected_components_ok() {
        let mut b = GraphBuilder::new();
        b.add("i1", OpKind::Scalar);
        b.add("i2", OpKind::Scalar);
        let g = b.build().unwrap();
        assert_eq!(g.sources().len(), 2);
        assert_eq!(g.topo_order().len(), 2);
    }
}
