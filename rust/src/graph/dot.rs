//! Graphviz DOT export, for eyeballing compiled model graphs.

use super::dag::Graph;

/// Render the graph in DOT format. Node color encodes the scalability
/// class; labels carry the mnemonic and flop volume.
pub fn to_dot(graph: &Graph) -> String {
    let mut out = String::from("digraph G {\n  rankdir=TB;\n  node [shape=box, style=filled, fontsize=10];\n");
    for node in graph.nodes() {
        let color = match node.kind.class() {
            crate::graph::op::OpClass::Gemm => "lightblue",
            crate::graph::op::OpClass::Conv => "lightgreen",
            crate::graph::op::OpClass::Elementwise => "lightyellow",
            crate::graph::op::OpClass::Memory => "lightgray",
            crate::graph::op::OpClass::Tiny => "white",
        };
        out.push_str(&format!(
            "  n{} [label=\"{}\\n{} {}F\", fillcolor={}];\n",
            node.id,
            escape(&node.name),
            node.kind.mnemonic(),
            crate::util::fmt_si(node.kind.flops()),
            color
        ));
    }
    for v in 0..graph.len() as u32 {
        for &s in graph.succs(v) {
            out.push_str(&format!("  n{v} -> n{s};\n"));
        }
    }
    out.push_str("}\n");
    out
}

fn escape(s: &str) -> String {
    s.replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    #[test]
    fn dot_contains_nodes_and_edges() {
        let mut b = GraphBuilder::new();
        let a = b.add("mat \"A\"", OpKind::MatMul { m: 2, k: 2, n: 2 });
        let c = b.add("act", OpKind::Scalar);
        b.depend(a, c);
        let dot = to_dot(&b.build().unwrap());
        assert!(dot.starts_with("digraph G {"));
        assert!(dot.contains("n0 -> n1;"));
        assert!(dot.contains("mat \\\"A\\\""));
        assert!(dot.contains("lightblue"));
        assert!(dot.ends_with("}\n"));
    }
}
