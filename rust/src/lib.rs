//! # Graphi
//!
//! A generic, high-performance execution engine for deep-learning
//! computation graphs on manycore CPUs — a full reproduction of
//! *"Scheduling Computation Graphs of Deep Learning Models on Manycore
//! CPUs"* (Tang, Wang, Willke, Li; 2018).
//!
//! The crate is organized in layers:
//!
//! * [`graph`]  — computation-graph IR (DAG of typed operations)
//! * [`models`] — graph compilers for the paper's four evaluation networks
//! * [`cost`]   — analytic operation cost model for the Intel Xeon Phi 7250
//! * [`sim`]    — discrete-event simulator of the KNL manycore topology
//! * [`engine`] — the paper's contribution: profiler, centralized
//!   critical-path-first scheduler, executor fleet, and the baseline
//!   engines it is evaluated against
//! * [`runtime`] — PJRT-backed execution of AOT-compiled JAX/Pallas
//!   artifacts (the real-compute path; Python never runs at request time)
//! * [`coordinator`] — experiment configs, drivers, metrics and reports
//! * [`util`]   — infrastructure substrates (CLI, JSON, bench harness, …)
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for reproduced results.

pub mod cli;
pub mod coordinator;
pub mod cost;
pub mod engine;
pub mod graph;
pub mod models;
pub mod runtime;
pub mod sim;
pub mod util;
