//! End-to-end training driver over the AOT-compiled `train_step` artifact.
//!
//! The Layer-2 JAX model (`python/compile/model.py`) lowers its full
//! training step — forward (Pallas LSTM cell), backward, SGD — into one
//! HLO module with signature:
//!
//! ```text
//! train_step(params: f32[P], tokens: f32[B, T+1]) -> (loss: f32[1], new_params: f32[P])
//! ```
//!
//! This driver owns the parameter vector, streams synthetic byte-level
//! corpus batches, calls the module once per step (pure Rust + PJRT; no
//! Python), and records the loss curve. Used by `graphi train` and
//! `examples/lstm_train.rs`; EXPERIMENTS.md logs a reference run.

use std::path::Path;

use crate::util::error::{Context, Result};
use crate::util::rng::Rng;

use super::artifacts::{tuning_path, tuning_path_for, ArtifactSet, MachineKey, TuningArtifact};
use super::pjrt::{LoadedModule, PjrtRuntime};

/// Tuning-artifact tag the training pipeline looks for in the artifact
/// directory (`<dir>/tuning/train_step.tuning.json`).
pub const TRAIN_TUNING_TAG: &str = "train_step";

/// The fallback parallel setting when no tuning artifact exists: one
/// executor over the full worker pool (the paper's S64 configuration).
pub const DEFAULT_TRAIN_PARALLELISM: (usize, usize) = (1, 64);

/// Load the training pipeline's persisted parallel setting, if the
/// autotuner has produced one for this artifact directory. Corrupt or
/// missing artifacts mean "no setting" — callers fall back to
/// [`DEFAULT_TRAIN_PARALLELISM`], they never fail.
pub fn load_parallel_setting(dir: impl AsRef<Path>) -> Option<(usize, usize)> {
    // prefer the machine-keyed filename (the training pipeline models the
    // paper's KNL quadrant part), fall back to the legacy location
    let machine = crate::cost::machine::Machine::knl7250();
    let keyed = tuning_path_for(&dir, TRAIN_TUNING_TAG, &MachineKey::of(&machine));
    let path = if keyed.is_file() { keyed } else { tuning_path(&dir, TRAIN_TUNING_TAG) };
    match TuningArtifact::load(&path) {
        // same guard as the CLI run path: an artifact hand-copied from a
        // differently-shaped machine is "no setting", not a setting
        Ok(t) if !t.matches_machine(&machine) => {
            crate::log_warn!(
                "tuning artifact {} was tuned on {} but this machine is {}; ignoring",
                path.display(),
                t.machine,
                MachineKey::of(&machine)
            );
            None
        }
        Ok(t) => {
            crate::log_info!(
                "parallel setting {}x{} from tuning artifact {}",
                t.best.0,
                t.best.1,
                path.display()
            );
            Some(t.best)
        }
        Err(_) => None,
    }
}

/// Synthetic byte-level corpus: a deterministic mixture of repeated
/// "words" with noise, so a language model has real structure to learn
/// (loss drops well below the uniform-entropy baseline).
pub struct SyntheticCorpus {
    text: Vec<u8>,
    cursor: usize,
}

impl SyntheticCorpus {
    pub fn new(seed: u64, len: usize) -> SyntheticCorpus {
        let mut rng = Rng::new(seed);
        let words: Vec<&[u8]> = vec![
            b"the ", b"quick ", b"brown ", b"fox ", b"jumps ", b"over ", b"lazy ", b"dog. ",
            b"graphi ", b"schedules ", b"graphs ", b"on ", b"manycore ", b"cpus. ",
        ];
        let mut text = Vec::with_capacity(len);
        while text.len() < len {
            text.extend_from_slice(words[rng.range(0, words.len())]);
            // occasional noise byte keeps the task from being trivial
            if rng.chance(0.02) {
                text.push(rng.below(256) as u8);
            }
        }
        text.truncate(len);
        SyntheticCorpus { text, cursor: 0 }
    }

    /// Next `[batch, seq+1]` token window (as f32 codes for the module).
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> Vec<f32> {
        let window = seq + 1;
        let mut out = Vec::with_capacity(batch * window);
        for _ in 0..batch {
            if self.cursor + window >= self.text.len() {
                self.cursor = 0;
            }
            out.extend(self.text[self.cursor..self.cursor + window].iter().map(|&b| b as f32));
            self.cursor += window;
        }
        out
    }

    pub fn len(&self) -> usize {
        self.text.len()
    }

    pub fn is_empty(&self) -> bool {
        self.text.is_empty()
    }
}

/// One training run's outcome.
#[derive(Debug)]
pub struct TrainReport {
    pub steps: usize,
    pub losses: Vec<f32>,
    pub wall_s: f64,
    pub steps_per_s: f64,
    pub params: usize,
}

impl TrainReport {
    pub fn initial_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }

    /// Mean of the last 10 % of steps.
    pub fn final_loss(&self) -> f32 {
        let tail = (self.losses.len() / 10).max(1);
        let s: f32 = self.losses[self.losses.len() - tail..].iter().sum();
        s / tail as f32
    }

    pub fn render_curve(&self, buckets: usize) -> String {
        let mut out = String::from("step    loss\n");
        let stride = (self.losses.len() / buckets.max(1)).max(1);
        for (i, loss) in self.losses.iter().enumerate().step_by(stride) {
            out.push_str(&format!("{i:6}  {loss:.4}\n"));
        }
        out.push_str(&format!(
            "{:6}  {:.4}  (final)\n",
            self.losses.len() - 1,
            self.losses.last().unwrap()
        ));
        out
    }
}

/// The trainer.
pub struct LstmTrainer {
    module: LoadedModule,
    params: Vec<f32>,
    batch: usize,
    seq: usize,
    /// `(executors, threads_per)` the execution fleet should use — from
    /// the artifact directory's tuning artifact when present, otherwise
    /// [`DEFAULT_TRAIN_PARALLELISM`].
    parallelism: (usize, usize),
    /// Did `parallelism` come from a tuning artifact (vs the default)?
    tuned: bool,
}

impl LstmTrainer {
    /// Load `train_step` from the artifact set and initialize parameters
    /// deterministically (scaled uniform, matching model.py's scheme).
    pub fn new(runtime: &PjrtRuntime, artifacts: &ArtifactSet, seed: u64) -> Result<LstmTrainer> {
        let module = runtime.load(artifacts, "train_step")?;
        let p = module.manifest.inputs[0][0];
        let batch = *module
            .manifest
            .meta
            .get("batch")
            .context("manifest missing meta.batch")? as usize;
        let seq = *module
            .manifest
            .meta
            .get("seq")
            .context("manifest missing meta.seq")? as usize;
        let scale = *module.manifest.meta.get("init_scale").unwrap_or(&0.1) as f32;
        let mut rng = Rng::new(seed);
        let params: Vec<f32> = (0..p)
            .map(|_| (rng.f64() as f32 * 2.0 - 1.0) * scale)
            .collect();
        let loaded = load_parallel_setting(&artifacts.dir);
        let tuned = loaded.is_some();
        let parallelism = loaded.unwrap_or(DEFAULT_TRAIN_PARALLELISM);
        Ok(LstmTrainer { module, params, batch, seq, parallelism, tuned })
    }

    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    /// The `(executors, threads_per)` fleet this trainer would run on.
    pub fn parallelism(&self) -> (usize, usize) {
        self.parallelism
    }

    /// Whether [`Self::parallelism`] came from a persisted tuning artifact
    /// rather than [`DEFAULT_TRAIN_PARALLELISM`].
    pub fn parallelism_from_tuning(&self) -> bool {
        self.tuned
    }

    /// Run one SGD step; returns the loss.
    pub fn step(&mut self, tokens: Vec<f32>) -> Result<f32> {
        let outputs = self
            .module
            .run_f32(&[std::mem::take(&mut self.params), tokens])
            .context("train_step execution")?;
        crate::ensure!(outputs.len() == 2, "train_step must return (loss, params)");
        let loss = outputs[0][0];
        self.params = outputs[1].clone();
        crate::ensure!(loss.is_finite(), "loss diverged to {loss}");
        Ok(loss)
    }

    /// Train for `steps` steps on a synthetic corpus.
    pub fn train(&mut self, steps: usize, corpus_seed: u64, log_every: usize) -> Result<TrainReport> {
        let mut corpus = SyntheticCorpus::new(corpus_seed, 1 << 20);
        let mut losses = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let batch = corpus.next_batch(self.batch, self.seq);
            let loss = self.step(batch)?;
            losses.push(loss);
            if log_every > 0 && step % log_every == 0 {
                crate::log_info!("step {step:5}  loss {loss:.4}");
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            steps,
            losses,
            wall_s,
            steps_per_s: steps as f64 / wall_s,
            params: self.params.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_structured() {
        let a = SyntheticCorpus::new(1, 10_000);
        let b = SyntheticCorpus::new(1, 10_000);
        assert_eq!(a.text, b.text);
        // structure: 'e' (from "the") far more common than random bytes
        let e_count = a.text.iter().filter(|&&c| c == b'e').count();
        assert!(e_count > a.len() / 50, "e count {e_count}");
    }

    #[test]
    fn batches_have_window_shape() {
        let mut c = SyntheticCorpus::new(2, 10_000);
        let batch = c.next_batch(8, 16);
        assert_eq!(batch.len(), 8 * 17);
        assert!(batch.iter().all(|&t| (0.0..256.0).contains(&t)));
    }

    #[test]
    fn batches_advance() {
        let mut c = SyntheticCorpus::new(3, 10_000);
        let a = c.next_batch(4, 8);
        let b = c.next_batch(4, 8);
        assert_ne!(a, b);
    }

    #[test]
    fn parallel_setting_loads_from_tuning_artifact() {
        use crate::engine::DispatchMode;
        use crate::runtime::artifacts::{MachineKey, TuningArtifact, TUNING_FORMAT_VERSION};
        let dir = std::env::temp_dir()
            .join(format!("graphi-train-tuning-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // absent → None (fresh checkout / pre-autotune)
        assert_eq!(load_parallel_setting(&dir), None);
        let artifact = TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: TRAIN_TUNING_TAG.to_string(),
            worker_cores: 64,
            seed: 1,
            machine: MachineKey { cores: 68, numa_domains: 1 },
            graph_nodes: 2,
            best: (8, 8),
            best_dispatch: DispatchMode::Centralized,
            phase_plan: None,
            width_plan: None,
            best_makespan_us: 10.0,
            total_profile_iterations: 5,
            durations_us: vec![1.0, 2.0],
            search_trace: Vec::new(),
        };
        artifact.save(tuning_path(&dir, TRAIN_TUNING_TAG)).unwrap();
        assert_eq!(load_parallel_setting(&dir), Some((8, 8)));
        // corrupt → None, not a panic
        std::fs::write(tuning_path(&dir, TRAIN_TUNING_TAG), "garbage").unwrap();
        assert_eq!(load_parallel_setting(&dir), None);
        // a machine-keyed artifact wins over the (corrupt) legacy file
        let keyed = tuning_path_for(
            &dir,
            TRAIN_TUNING_TAG,
            &MachineKey { cores: 68, numa_domains: 1 },
        );
        TuningArtifact { best: (4, 16), ..artifact.clone() }.save(&keyed).unwrap();
        assert_eq!(load_parallel_setting(&dir), Some((4, 16)));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn report_statistics() {
        let r = TrainReport {
            steps: 100,
            losses: (0..100).map(|i| 5.0 - 0.04 * i as f32).collect(),
            wall_s: 10.0,
            steps_per_s: 10.0,
            params: 1000,
        };
        assert_eq!(r.initial_loss(), 5.0);
        assert!(r.final_loss() < 1.5);
        assert!(r.render_curve(10).contains("final"));
    }
}
