//! The real-compute path: PJRT execution of AOT-compiled JAX/Pallas
//! artifacts, plus the real-threads Graphi engine.
//!
//! `make artifacts` runs Python **once** (build time): `python/compile/`
//! lowers the JAX LSTM-LM (whose cell math is a Pallas kernel) to HLO
//! *text* — the interchange format xla_extension 0.5.1 accepts (see
//! /opt/xla-example/README.md). At run time this module loads, compiles,
//! and executes those artifacts through the PJRT CPU client; Python is
//! never on the request path.
//!
//! * [`artifacts`] — artifact discovery + JSON manifest parsing, plus the
//!   persisted tuning artifacts the autotuner writes and later runs load
//! * [`pjrt`]      — client/executable wrappers over the `xla` crate
//! * [`fleet`]     — persistent executor fleets and per-graph serving
//!   sessions (threads spawned once, many graphs in flight, §5.1
//!   memory-budget admission)
//! * [`threaded`]  — the Graphi scheduler driving *real* host threads,
//!   now submit-one-session-and-wait on the fleet core; used by the
//!   end-to-end training example and as proof the engine is not sim-only
//! * [`serve`]     — the multi-model serving driver behind `graphi serve`:
//!   closed-loop clients or open-loop Poisson/bursty arrivals, pluggable
//!   admission order, SLO-aware shedding, and offered-load knee sweeps
//! * [`telemetry`] — serve-mode observability: the bounded ring of recent
//!   session samples and the periodic aggregate snapshots printed by
//!   `graphi serve --telemetry-every-ms`

pub mod artifacts;
pub mod fleet;
pub mod pjrt;
pub mod serve;
pub mod telemetry;
pub mod threaded;
pub mod train;

pub use artifacts::{
    autotune_or_load, tuning_path, tuning_path_for, ArtifactSet, MachineKey, Manifest,
    TuneOutcome, TuningArtifact,
};
pub use fleet::{
    AdmissionPermit, AdmissionPolicy, AdmitRequest, Fleet, FleetConfig, FleetError, FleetTotals,
    SessionError, SessionHandle, SessionQueue, SessionReport, ShedReason,
};
pub use pjrt::{LoadedModule, PjrtRuntime};
pub use serve::{
    serve, serve_sweep, Arrival, BatchGroup, BatchJoin, BatchMember, Batcher, ServeConfig,
    ServeReport, SweepPoint, SweepReport,
};
pub use telemetry::{OutcomeClass, SessionSample, TelemetryRing, TelemetrySnapshot};
pub use threaded::{ThreadedGraphi, UnsupportedPolicy};
pub use train::{load_parallel_setting, LstmTrainer, SyntheticCorpus, TrainReport};
