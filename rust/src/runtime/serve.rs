//! Multi-model serving on one persistent executor fleet — the engine
//! behind `graphi serve` — under two load models:
//!
//! * **Closed loop** (default, [`Arrival::Closed`]): a fixed pool of
//!   client threads replays a weighted model mix against a single
//!   [`Fleet`], each client blocking on its session before issuing the
//!   next request. Offered load ≈ `clients / mean latency`, so the
//!   generator self-throttles and structurally cannot expose queueing
//!   collapse — useful for capacity measurement, blind to overload.
//! * **Open loop** ([`Arrival::Poisson`] / [`Arrival::Bursty`]): a
//!   deterministic seeded arrival schedule (drawn once from
//!   [`crate::util::rng::Rng`]) is replayed by a dispatcher thread that
//!   spawns one request thread per arrival *regardless of how the fleet
//!   is doing* — offered load is fixed at `rps`, and overload has to go
//!   somewhere. Bursty arrivals are an on/off process (exponential on
//!   windows, 4× the target rate inside a burst) averaging the same
//!   `rps`, for tail behaviour under clustered arrivals.
//!
//! Where overload goes is the **admission frontier** ([`SessionQueue`]):
//! every request still pays §5.1 memory admission (budgeted on the
//! model's planned peak arena footprint), ordered by a pluggable
//! [`AdmissionPolicy`] — FIFO, priority classes (with aging), or EDF
//! over per-request deadlines. Under pressure the queue **sheds**
//! structurally instead of queueing forever: a depth cap bounds the
//! line ([`ShedReason::QueueFull`]), the deadline bounds the wait
//! ([`ShedReason::AdmissionTimeout`]), and — in open-loop runs with a
//! deadline — a grant-pace estimator rejects requests whose predicted
//! wait already exceeds their patience ([`ShedReason::PredictedLate`]).
//! Shed requests are never submitted; they are counted per reason, flow
//! into [`FleetTotals::sessions_shed`] and the telemetry snapshots, and
//! appear in the report's outcome accounting so that
//! `completed + failed + cancelled + deadline_missed + shed == requests`
//! exactly.
//!
//! Riding on that frontier is **cross-session dynamic batching**
//! ([`Batcher`], ROADMAP item 1): with [`ServeConfig::max_batch`] > 1,
//! open-loop requests for the *same* zoo entry (one `(ModelKind,
//! ModelSize, training)` combination) that arrive within
//! [`ServeConfig::batch_window_us`] of the first waiter merge into **one
//! fleet session** over [`Graph::disjoint_union`], so the fleet pays
//! per-session dispatch and admission cost once instead of `k` times.
//! The batching rules:
//!
//! * The first request of a group is the **leader**: it waits out the
//!   window (cut short the instant the group fills to `max_batch`),
//!   then admits and submits for everyone. The window wait counts
//!   against every member's latency.
//! * A batch is **one admission-queue entry**: it charges the *sum* of
//!   its members' planned peaks (the components execute concurrently,
//!   so their arenas coexist), carries the most urgent member class,
//!   and — on shed — sheds every member, one counted shed each.
//! * The one `SessionReport` fans back out per member: each logical
//!   request gets its own latency sample, outcome class, telemetry ring
//!   sample, and Chrome-trace lifecycle lane, so request-level
//!   conservation stays exact whether or not requests were merged.
//! * Requests that drew a fault plan (panic / delay / cancel) never
//!   batch — a fault must stay confined to its own request — and a zoo
//!   entry whose union would exceed the fleet's packed-key node limit
//!   caps its own batch size.
//!
//! [`serve_sweep`] replays the same configuration across a list of
//! offered loads and reports the **latency-vs-throughput knee**: the
//! highest offered rps that still completes ≥90 % of its offered load
//! with <5 % shed — the operating point a load balancer should steer to.
//!
//! Two observability taps ride on the loop (both off by default):
//! [`ServeConfig::trace_path`] writes one Chrome/Perfetto trace with a
//! pid per session — op spans are collected for `1-in-N` sessions
//! ([`ServeConfig::trace_sample`]) so the trace stays bounded on long
//! runs, while session lifecycle instants (admitted / done / failed /
//! deadline / …) are always recorded for **every** session — and
//! [`ServeConfig::telemetry_every_ms`] prints periodic aggregate
//! snapshots (now including the shed rate) from a bounded
//! [`TelemetryRing`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::ready::MAX_WIDTH;
use crate::engine::trace::{export_chrome_trace, OpRecord, SessionTraceExport};
use crate::engine::{DispatchMode, WidthPlan};
use crate::graph::{levels as cp_levels, plan_memory, Graph, NodeId};
use crate::models::{self, ModelKind, ModelSize};
use crate::runtime::fleet::{
    AdmissionPolicy, AdmitRequest, Fleet, FleetConfig, FleetTotals, SessionError, SessionQueue,
    ShedReason, MAX_SESSION_NODES,
};
use crate::runtime::telemetry::{OutcomeClass, SessionSample, TelemetryRing, TelemetrySnapshot};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::testkit::FaultPlan;

/// How requests arrive at the fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// `clients` threads, zero think time: offered load tracks capacity.
    Closed,
    /// Open loop: seeded Poisson arrivals at `rps` offered load.
    Poisson { rps: f64 },
    /// Open loop: seeded on/off arrivals averaging `rps` — inside an
    /// exponential on-window arrivals run at 4× the target rate, between
    /// windows nothing arrives.
    Bursty { rps: f64 },
}

impl Arrival {
    pub fn name(self) -> &'static str {
        match self {
            Arrival::Closed => "closed",
            Arrival::Poisson { .. } => "poisson",
            Arrival::Bursty { .. } => "bursty",
        }
    }

    /// The offered load, `None` for the closed loop (where it is an
    /// outcome, not a parameter).
    pub fn offered_rps(self) -> Option<f64> {
        match self {
            Arrival::Closed => None,
            Arrival::Poisson { rps } | Arrival::Bursty { rps } => Some(rps),
        }
    }
}

/// Burst intensity of [`Arrival::Bursty`]: arrival rate inside an
/// on-window, as a multiple of the long-run average.
const BURST_FACTOR: f64 = 4.0;
/// Mean on-window length of [`Arrival::Bursty`], µs.
const BURST_ON_US: f64 = 10_000.0;

/// Draw the whole arrival schedule up front (offsets from run start,
/// µs): replaying it is what makes an open-loop run deterministic per
/// seed regardless of how the fleet schedules.
fn arrival_offsets_us(arrival: Arrival, n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed ^ 0xA881_7A1E);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    match arrival {
        Arrival::Closed => unreachable!("closed-loop runs have no arrival schedule"),
        Arrival::Poisson { rps } => {
            assert!(rps.is_finite() && rps > 0.0, "poisson arrivals need rps > 0");
            for _ in 0..n {
                t += rng.exponential(1e6 / rps);
                // round to the nearest µs: `as u64` truncates toward zero,
                // which at high rps systematically drags offsets early and
                // collapses sub-µs gaps worse than rounding does
                out.push(t.round() as u64);
            }
        }
        Arrival::Bursty { rps } => {
            assert!(rps.is_finite() && rps > 0.0, "bursty arrivals need rps > 0");
            // on-time budget left in the current burst window; crossing it
            // inserts an off window sized so the long-run average is `rps`
            // (1/BURST_FACTOR of the time on, at BURST_FACTOR × the rate)
            let mut on_left = rng.exponential(BURST_ON_US);
            for _ in 0..n {
                let mut gap = rng.exponential(1e6 / (BURST_FACTOR * rps));
                while gap > on_left {
                    gap -= on_left;
                    t += on_left + rng.exponential((BURST_FACTOR - 1.0) * BURST_ON_US);
                    on_left = rng.exponential(BURST_ON_US);
                }
                on_left -= gap;
                t += gap;
                out.push(t.round() as u64);
            }
        }
    }
    out
}

/// One serve experiment.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Executor threads in the (single, shared) fleet.
    pub executors: usize,
    /// Fleet dispatch architecture for this run.
    pub dispatch: DispatchMode,
    /// Closed-loop client threads (ignored by open-loop arrivals, where
    /// concurrency is whatever the arrival process piles up).
    pub clients: usize,
    /// Total requests to offer.
    pub requests: usize,
    /// Arrival process; open-loop kinds carry their offered load.
    pub arrival: Arrival,
    /// Admission order of the §5.1 queue (FIFO / priority / EDF). With
    /// `Priority`, request classes are drawn 0–2 seeded (0 most urgent).
    pub admission: AdmissionPolicy,
    /// Bounded admission line: arrivals beyond this many waiters are
    /// shed immediately ([`ShedReason::QueueFull`]).
    pub queue_depth: Option<u64>,
    /// Weighted model mix (weights need not sum to 1).
    pub mix: Vec<(ModelKind, f64)>,
    pub size: ModelSize,
    /// Serve training graphs instead of forward-only inference graphs.
    pub training: bool,
    /// §5.1 admission budget over planned peak arena footprints.
    pub budget_bytes: u64,
    /// Fleet session-slot cap.
    pub max_sessions: usize,
    /// Busy-spin per op, µs (0 ⇒ scheduling-only, the dispatch-throughput
    /// regime the paper's small-op argument is about).
    pub op_spin_us: f64,
    /// Probability a request draws a fault plan (op panic / op delay /
    /// client cancel), split evenly between the three kinds; seeded, so a
    /// given `(seed, fault_rate)` replays the same fault schedule per
    /// client. 0 keeps the zero-allocation borrowed-closure hot path.
    pub fault_rate: f64,
    /// Per-session deadline, µs. Sessions past it terminate with
    /// [`SessionError::DeadlineExceeded`]; admission waits are bounded by
    /// the same patience and time-outs are **shed** (counted, not run).
    /// Open-loop runs with a deadline also enable predictive shedding
    /// ([`SessionQueue::with_wait_prediction`]).
    pub deadline_us: Option<u64>,
    /// Write a per-session Chrome/Perfetto trace of the whole run here
    /// (turns on fleet event recording and session record collection).
    pub trace_path: Option<String>,
    /// Op-span sampling for the trace: spans are kept for one session in
    /// every `trace_sample` (request indices `0, N, 2N, …`); lifecycle
    /// instants are always kept for every session. 1 ⇒ sample everything.
    pub trace_sample: u64,
    /// Print one aggregate telemetry line every this-many milliseconds
    /// while the run is live. The final snapshot is collected either way.
    pub telemetry_every_ms: Option<u64>,
    /// Capacity of the bounded ring of recent session samples that
    /// telemetry snapshots aggregate over.
    pub telemetry_ring: usize,
    /// Cross-session dynamic batching: open-loop requests for the same
    /// zoo entry arriving within this window of the first waiter merge
    /// into one fleet session (see the module docs). Only consulted when
    /// `max_batch > 1`.
    pub batch_window_us: u64,
    /// Max logical requests per merged session. 1 (the default) disables
    /// batching entirely and keeps the pre-batching serve path
    /// bit-for-bit. Values > 1 require an open-loop arrival process.
    pub max_batch: usize,
    /// Per-op-class gang-width plan (moldable ops): ops of a molded
    /// class are submitted as width-`w` gangs via
    /// [`Fleet::submit_moldable`], with tiny ops pinned to width 1 and
    /// widths clamped to the fleet size. `None` (the default) keeps
    /// every pre-moldable submit path — including its zero-allocation
    /// borrowed closures — bit-for-bit.
    pub width_plan: Option<WidthPlan>,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            executors: 4,
            dispatch: DispatchMode::Decentralized,
            clients: 4,
            requests: 200,
            arrival: Arrival::Closed,
            admission: AdmissionPolicy::Fifo,
            queue_depth: None,
            mix: vec![
                (ModelKind::Lstm, 1.0),
                (ModelKind::Mlp, 1.0),
                (ModelKind::GoogleNet, 1.0),
                (ModelKind::PathNet, 1.0),
            ],
            size: ModelSize::Small,
            training: false,
            // §7.1: the machine's 16 GB MCDRAM is the natural budget
            budget_bytes: 16 << 30,
            max_sessions: 32,
            op_spin_us: 0.0,
            fault_rate: 0.0,
            deadline_us: None,
            trace_path: None,
            trace_sample: 1,
            telemetry_every_ms: None,
            telemetry_ring: 1024,
            batch_window_us: 200,
            max_batch: 1,
            width_plan: None,
            seed: 42,
        }
    }
}

/// Outcome of one [`serve`] run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub dispatch: DispatchMode,
    /// Offered load for open-loop runs (`None` for the closed loop).
    pub offered_rps: Option<f64>,
    /// Total requests offered to the run ([`ServeConfig::requests`]) —
    /// the right-hand side of the conservation identity
    /// [`accounted`](Self::accounted)` == offered`.
    pub offered: usize,
    pub completed: usize,
    pub wall_s: f64,
    /// Completed sessions per second over the whole run.
    pub throughput_rps: f64,
    /// Session latency summary (admission wait + execution), µs.
    pub latency_us: Summary,
    /// `(model tag, sessions completed, planned peak bytes)` per mix entry.
    pub per_model: Vec<(String, u64, u64)>,
    /// Fleet-lifetime counter totals.
    pub totals: FleetTotals,
    /// Σ of per-session dispatch counters (must equal the fleet total).
    pub session_dispatches: u64,
    /// Σ of per-session steal counters (≤ the fleet total).
    pub session_steals: u64,
    /// Peak concurrently-in-flight sessions observed.
    pub max_in_flight: usize,
    /// Requests that blocked in admission before fitting the budget.
    pub admission_blocked: u64,
    /// Requests whose session terminated with an op panic
    /// ([`SessionError::OpPanicked`]). Counted per *logical request*: a
    /// batched session's terminal counts once per member.
    pub failed: u64,
    /// Requests whose session was cancelled ([`SessionError::Cancelled`]),
    /// per logical request.
    pub cancelled: u64,
    /// Requests whose session ran past its deadline
    /// ([`SessionError::DeadlineExceeded`]), per logical request.
    pub deadline_missed: u64,
    /// Requests shed at admission (never submitted): timed out, bounced
    /// off the depth cap, or predicted hopeless.
    pub shed: u64,
    /// Shed counts split by [`ShedReason`] (nonzero reasons only).
    pub shed_reasons: Vec<(String, u64)>,
    /// Latency summaries split by outcome class (`ok` / `failed` /
    /// `cancelled` / `deadline`); only classes with ≥1 sample appear.
    pub latency_by_class: Vec<(String, Summary)>,
    /// Telemetry snapshots collected over the run: one per
    /// [`ServeConfig::telemetry_every_ms`] interval plus always one final
    /// snapshot, so this is never empty.
    pub snapshots: Vec<TelemetrySnapshot>,
    /// Fraction of offered requests that ran inside a multi-request
    /// batch (groups of ≥2). 0.0 whenever batching is off.
    pub batched_fraction: f64,
    /// Batch-size histogram: `(group size, groups formed)` for every
    /// size that occurred, including size-1 groups whose window expired
    /// with no joiner. Empty when batching is off.
    pub batch_sizes: Vec<(usize, u64)>,
}

impl ServeReport {
    /// Every request the run accounted for — the conservation total.
    pub fn accounted(&self) -> u64 {
        self.completed as u64 + self.failed + self.cancelled + self.deadline_missed + self.shed
    }

    /// Fraction of offered requests shed at admission.
    pub fn shed_fraction(&self) -> f64 {
        self.shed as f64 / (self.accounted().max(1)) as f64
    }

    /// Fraction of offered requests that completed — the goodput ratio
    /// the knee criterion uses (robust to wall-clock noise, unlike an
    /// achieved-vs-offered rps ratio on short runs).
    pub fn completed_fraction(&self) -> f64 {
        self.completed as f64 / (self.accounted().max(1)) as f64
    }

    /// One-screen human-readable summary.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "== serve ({} dispatch) ==", self.dispatch.name());
        let _ = writeln!(
            out,
            "{} sessions in {:.2}s  →  {:.1} sessions/s",
            self.completed, self.wall_s, self.throughput_rps
        );
        if let Some(offered) = self.offered_rps {
            let _ = writeln!(
                out,
                "open loop: offered {:.1} rps → achieved {:.1} rps  ({:.1}% shed)",
                offered,
                self.throughput_rps,
                self.shed_fraction() * 100.0
            );
        }
        let _ = writeln!(
            out,
            "session latency: p50 {}  p99 {}  max {}",
            crate::util::fmt_us(self.latency_us.p50),
            crate::util::fmt_us(self.latency_us.p99),
            crate::util::fmt_us(self.latency_us.max),
        );
        for (tag, n, bytes) in &self.per_model {
            let _ = writeln!(
                out,
                "  {tag:12} {n:6} sessions  (planned peak {})",
                crate::util::fmt_si(*bytes as f64)
            );
        }
        let _ = writeln!(
            out,
            "fleet: {} dispatches  {} steals ({} cross-domain)  {} parks  | per-session sums: {} dispatches, {} steals",
            self.totals.dispatches,
            self.totals.steals,
            self.totals.cross_domain_steals,
            self.totals.parks,
            self.session_dispatches,
            self.session_steals,
        );
        if self.totals.gangs_formed > 0 {
            let _ = writeln!(
                out,
                "moldable: {} gangs formed  {} members recruited",
                self.totals.gangs_formed, self.totals.gang_recruits
            );
        }
        let _ = writeln!(
            out,
            "concurrency: ≤{} sessions in flight  |  admission: {} requests waited on the memory budget",
            self.max_in_flight, self.admission_blocked
        );
        let _ = writeln!(
            out,
            "faults: {} failed  {} cancelled  {} deadline_missed  {} shed",
            self.failed, self.cancelled, self.deadline_missed, self.shed
        );
        let _ = writeln!(
            out,
            "accounted: {}/{} requests (completed+failed+cancelled+deadline_missed+shed)",
            self.accounted(),
            self.offered
        );
        if !self.batch_sizes.is_empty() {
            let batched: u64 =
                self.batch_sizes.iter().filter(|(k, _)| *k >= 2).map(|(k, n)| *k as u64 * n).sum();
            let _ = write!(
                out,
                "batching: {}/{} requests in multi-request batches ({:.1}%)  groups:",
                batched,
                self.offered,
                self.batched_fraction * 100.0
            );
            for (k, n) in &self.batch_sizes {
                let _ = write!(out, " {k}×{n}");
            }
            let _ = writeln!(out);
        }
        if !self.shed_reasons.is_empty() {
            let _ = write!(out, "  shed by reason:");
            for (reason, n) in &self.shed_reasons {
                let _ = write!(out, "  {reason}={n}");
            }
            let _ = writeln!(out);
        }
        for (class, s) in &self.latency_by_class {
            let _ = writeln!(
                out,
                "  class {class:9} n={:<6} p50 {}  p99 {}",
                s.n,
                crate::util::fmt_us(s.p50),
                crate::util::fmt_us(s.p99),
            );
        }
        if let Some(snap) = self.snapshots.last() {
            let _ = writeln!(out, "{}", snap.render_line());
        }
        out
    }
}

/// A pre-built `k`-way disjoint union of one zoo entry's graph, with CP
/// levels recomputed on the union (equal to the per-component levels —
/// the [`Graph::disjoint_union`] property — but computed once here so a
/// batch submit is as allocation-free as a solo submit).
struct BatchedGraph {
    graph: Graph,
    levels: Arc<[f64]>,
    /// Per-node gang widths for the union (see [`derive_widths`]).
    widths: Option<Arc<[u8]>>,
}

struct ZooEntry {
    tag: String,
    graph: Graph,
    levels: Arc<[f64]>,
    peak_bytes: u64,
    weight: f64,
    /// Union graphs for batch sizes `2..`, index `k-2`; truncated where
    /// `k·len` would hit the fleet's packed-key node limit. Empty when
    /// batching is off.
    batched: Vec<BatchedGraph>,
    /// Per-node gang widths resolved from [`ServeConfig::width_plan`];
    /// `None` routes this entry through the pre-moldable submit paths.
    widths: Option<Arc<[u8]>>,
}

/// Resolve a [`WidthPlan`] against one zoo graph: per-node requested
/// gang widths by op class, with tiny ops pinned to width 1 (a gang
/// barrier costs more than the op) and everything clamped to the fleet
/// size. Returns `None` when every node resolves to width 1, so a
/// uniform-1 plan keeps the pre-moldable submit paths bit-for-bit.
fn derive_widths(graph: &Graph, plan: &WidthPlan, executors: usize) -> Option<Arc<[u8]>> {
    let cap = executors.clamp(1, MAX_WIDTH as usize) as u32;
    let widths: Vec<u8> = graph
        .nodes()
        .iter()
        .map(|n| {
            if n.kind.is_tiny() {
                1
            } else {
                plan.width_for(n.kind.class()).min(cap) as u8
            }
        })
        .collect();
    if widths.iter().all(|&w| w == 1) {
        return None;
    }
    Some(widths.into())
}

/// One logical request waiting in a batch group: everything the group
/// leader needs to admit, account, and trace on the member's behalf.
#[derive(Debug, Clone, Copy)]
pub struct BatchMember {
    /// Request index within the run (trace-sampling identity).
    pub index: usize,
    /// Admission priority class (0 most urgent).
    pub class: u8,
    /// The member's own arrival instant — per-member latency is measured
    /// from here, so the batch-window wait is charged to every member.
    pub t0: Instant,
}

struct BatchState {
    members: Vec<BatchMember>,
    closed: bool,
}

/// One forming batch. Opaque: obtained from [`Batcher::join`] and handed
/// back to [`Batcher::close`] by the group's leader.
pub struct BatchGroup {
    state: Mutex<BatchState>,
    /// Signalled by the joiner that fills the group, so the leader's
    /// window wait ends the moment the batch is full.
    full: Condvar,
}

/// How [`Batcher::join`] placed a request.
pub enum BatchJoin {
    /// First in line: wait out the window via [`Batcher::close`], then
    /// admit/submit/account for every member.
    Leader(Arc<BatchGroup>),
    /// Joined an open group; the leader resolves this request end to
    /// end — the follower is done the moment it joins.
    Follower,
}

/// Cross-session dynamic batching at the admission frontier (ROADMAP
/// item 1): one open group slot per compatibility key (the serve loop
/// keys by zoo entry, i.e. `(ModelKind, ModelSize, training)`). See the
/// module docs for the batching rules.
///
/// Lock order: a slot's lock is always taken **before** its group's
/// state lock; [`close`](Self::close) re-acquires in that order after
/// its window wait, which is what makes leader close and joiner fill
/// race-free.
pub struct Batcher {
    open: Vec<Mutex<Option<Arc<BatchGroup>>>>,
    window: Duration,
}

impl Batcher {
    /// `slots` compatibility keys, one bounded window for all of them.
    pub fn new(slots: usize, window: Duration) -> Batcher {
        Batcher { open: (0..slots).map(|_| Mutex::new(None)).collect(), window }
    }

    /// Join `slot`'s open group (capped at `cap` members), or open a new
    /// group and become its leader. `cap` must be ≥2 — callers that
    /// cannot batch a key at all should bypass the batcher entirely.
    pub fn join(&self, slot: usize, member: BatchMember, cap: usize) -> BatchJoin {
        debug_assert!(cap >= 2, "a batch cap of {cap} cannot merge anything");
        let mut open = self.open[slot].lock().unwrap();
        if let Some(group) = open.as_ref() {
            let group = Arc::clone(group);
            let mut st = group.state.lock().unwrap();
            if !st.closed && st.members.len() < cap {
                st.members.push(member);
                if st.members.len() == cap {
                    // the filler closes the group: retire the slot (still
                    // held) and wake the leader out of its window wait
                    st.closed = true;
                    group.full.notify_one();
                    drop(st);
                    *open = None;
                }
                return BatchJoin::Follower;
            }
        }
        let group = Arc::new(BatchGroup {
            state: Mutex::new(BatchState { members: vec![member], closed: false }),
            full: Condvar::new(),
        });
        *open = Some(Arc::clone(&group));
        BatchJoin::Leader(group)
    }

    /// Leader only: wait out the batch window (cut short if a joiner
    /// fills the group), retire the slot, and take the members. The
    /// leader is always `members[0]`.
    pub fn close(&self, slot: usize, group: &Arc<BatchGroup>) -> Vec<BatchMember> {
        let deadline = Instant::now() + self.window;
        let mut st = group.state.lock().unwrap();
        while !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = group.full.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        drop(st);
        // slot before state — the same order join() takes
        let mut open = self.open[slot].lock().unwrap();
        if let Some(g) = open.as_ref() {
            if Arc::ptr_eq(g, group) {
                *open = None;
            }
        }
        let mut st = group.state.lock().unwrap();
        st.closed = true;
        std::mem::take(&mut st.members)
    }
}

/// Everything the Chrome-trace exporter needs about one finished session.
/// Failed/cancelled sessions appear with empty records (the fleet drops
/// their partial trace), and so do completed-but-unsampled ones
/// ([`ServeConfig::trace_sample`]); both keep their lifecycle instants
/// and terminal cause.
struct CollectedSession {
    zoo: usize,
    seq: u64,
    /// Position within the fleet session's batch (0 for solo requests):
    /// every member of a merged session keeps its own lifecycle lane.
    member: usize,
    /// Batch size of the fleet session this request rode in (1 = solo).
    of: usize,
    submit_us: f64,
    end_us: f64,
    outcome: String,
    records: Vec<OpRecord>,
}

fn reason_idx(reason: ShedReason) -> usize {
    match reason {
        ShedReason::AdmissionTimeout => 0,
        ShedReason::QueueFull => 1,
        ShedReason::PredictedLate => 2,
    }
}

const REASON_NAMES: [&str; 3] = ["admission_timeout", "queue_full", "predicted_late"];

/// Open-loop backpressure of last resort: the dispatcher stops spawning
/// request threads (and sheds instead) once this many are live, so a
/// pathological offered load cannot exhaust OS threads.
fn live_request_cap(max_sessions: usize) -> usize {
    4 * max_sessions + 64
}

/// Run one serve experiment; see the module docs.
pub fn serve(cfg: &ServeConfig) -> ServeReport {
    assert!(cfg.executors >= 1 && cfg.clients >= 1 && cfg.requests >= 1);
    assert!(!cfg.mix.is_empty(), "empty model mix");
    assert!(cfg.trace_sample >= 1, "trace_sample is 1-in-N with N >= 1");
    assert!((1..=256).contains(&cfg.max_batch), "max_batch must be in 1..=256");
    let total_weight: f64 = cfg.mix.iter().map(|(_, w)| w).sum();
    assert!(total_weight > 0.0, "mix weights must sum to something positive");

    // Pre-build the zoo once: graph, CP levels from the analytic cost
    // model, the §5.1 planned peak footprint that admission charges, and
    // — with batching on — the k-way disjoint unions batches submit, so
    // the serve hot path never builds a graph.
    let cost = crate::cost::CostModel::knl();
    let zoo: Vec<ZooEntry> = cfg
        .mix
        .iter()
        .map(|&(kind, weight)| {
            let graph = if cfg.training {
                models::build(kind, cfg.size)
            } else {
                models::build_inference(kind, cfg.size)
            };
            let durations: Vec<f64> =
                graph.nodes().iter().map(|n| cost.duration_us(&n.kind, 8)).collect();
            let levels: Arc<[f64]> = cp_levels(&graph, &durations).into();
            let peak_bytes = plan_memory(&graph, &graph.topo_order()).arena_bytes;
            let batched: Vec<BatchedGraph> = (2..=cfg.max_batch)
                .take_while(|&k| k * graph.len() < MAX_SESSION_NODES)
                .map(|k| {
                    let copies: Vec<&Graph> = vec![&graph; k];
                    let (union, _) = Graph::disjoint_union(&copies);
                    let durs: Vec<f64> =
                        union.nodes().iter().map(|n| cost.duration_us(&n.kind, 8)).collect();
                    let levels: Arc<[f64]> = cp_levels(&union, &durs).into();
                    let widths = cfg
                        .width_plan
                        .as_ref()
                        .and_then(|p| derive_widths(&union, p, cfg.executors));
                    BatchedGraph { graph: union, levels, widths }
                })
                .collect();
            let widths =
                cfg.width_plan.as_ref().and_then(|p| derive_widths(&graph, p, cfg.executors));
            ZooEntry {
                tag: format!(
                    "{}-{}{}",
                    kind.name(),
                    cfg.size.name(),
                    if cfg.training { "" } else { "-inf" }
                ),
                graph,
                levels,
                peak_bytes,
                weight,
                batched,
                widths,
            }
        })
        .collect();

    const CLASSES: [&str; 4] = ["ok", "failed", "cancelled", "deadline"];
    let open_loop = cfg.arrival != Arrival::Closed;
    assert!(
        cfg.max_batch == 1 || open_loop,
        "cross-session batching (max_batch > 1) requires an open-loop arrival process: \
         the closed loop self-throttles, so there is nothing waiting to merge"
    );
    // per-zoo batch cap: the configured cap, clamped where the union
    // table was truncated by the session node limit
    let batch_cap: Vec<usize> =
        zoo.iter().map(|z| cfg.max_batch.min(z.batched.len() + 1)).collect();
    let batcher = Batcher::new(zoo.len(), Duration::from_micros(cfg.batch_window_us));
    let batched_requests = AtomicU64::new(0);
    let batch_groups: Vec<AtomicU64> = (0..cfg.max_batch).map(|_| AtomicU64::new(0)).collect();
    let schedule: Vec<u64> = if open_loop {
        arrival_offsets_us(cfg.arrival, cfg.requests, cfg.seed)
    } else {
        Vec::new()
    };
    let mut queue = SessionQueue::new(cfg.budget_bytes).with_policy(cfg.admission);
    if let Some(depth) = cfg.queue_depth {
        queue = queue.with_depth_cap(depth);
    }
    if open_loop && cfg.deadline_us.is_some() {
        // closed-loop runs keep the pre-prediction admission behaviour
        // bit-for-bit; open-loop SLO runs get the estimator
        queue = queue.with_wait_prediction();
    }
    let queue = queue;
    let next_request = AtomicUsize::new(0);
    let completed_per_model: Vec<AtomicU64> = zoo.iter().map(|_| AtomicU64::new(0)).collect();
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(cfg.requests));
    let by_class: [Mutex<Vec<f64>>; 4] =
        [Mutex::new(Vec::new()), Mutex::new(Vec::new()), Mutex::new(Vec::new()), Mutex::new(Vec::new())];
    let session_dispatches = AtomicU64::new(0);
    let session_steals = AtomicU64::new(0);
    let in_flight = AtomicUsize::new(0);
    let max_in_flight = AtomicUsize::new(0);
    let admission_blocked = AtomicU64::new(0);
    let shed_by_reason: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
    let ring = TelemetryRing::new(cfg.telemetry_ring);
    let snapshots: Mutex<Vec<TelemetrySnapshot>> = Mutex::new(Vec::new());
    let collect_trace = cfg.trace_path.is_some();
    let collected: Mutex<Vec<CollectedSession>> = Mutex::new(Vec::new());
    // requests not yet resolved to an outcome; the telemetry monitor (and
    // nothing else) watches this hit 0
    let outstanding = AtomicUsize::new(cfg.requests);
    // request threads currently live in an open-loop run (soft cap)
    let live_requests = AtomicUsize::new(0);
    // ring sample class per by_class index (the report's CLASSES order)
    const CLASS_OUTCOMES: [OutcomeClass; 4] =
        [OutcomeClass::Ok, OutcomeClass::Failed, OutcomeClass::Cancelled, OutcomeClass::Deadline];
    let deadline = cfg.deadline_us.map(Duration::from_micros);
    // delay faults sleep long enough to trip a tight deadline (2×, capped
    // at 50ms so generous deadlines don't stall the run); without a
    // deadline they just stretch the session's tail latency
    let fault_delay_us = cfg.deadline_us.map(|d| (d as f64 * 2.0).min(50_000.0)).unwrap_or(200.0);
    let spin_us = cfg.op_spin_us;
    let work = move |_n: NodeId| {
        if spin_us > 0.0 {
            let t0 = Instant::now();
            while t0.elapsed().as_secs_f64() * 1e6 < spin_us {
                std::hint::spin_loop();
            }
        }
    };
    let work_ref: &(dyn Fn(NodeId) + Send + Sync) = &work;
    // moldable variant: a width-`w` gang splits the op's spin across its
    // seats, the USL-ish ideal the gang-formation overhead competes with
    let wide_work: Arc<dyn Fn(NodeId, u32, u32) + Send + Sync> =
        Arc::new(move |_n: NodeId, _rank: u32, width: u32| {
            let spin = spin_us / width.max(1) as f64;
            if spin > 0.0 {
                let t0 = Instant::now();
                while t0.elapsed().as_secs_f64() * 1e6 < spin {
                    std::hint::spin_loop();
                }
            }
        });

    let t_start = Instant::now();
    let (totals, fleet_events) = std::thread::scope(|scope| {
        let fleet = Fleet::new(
            scope,
            FleetConfig {
                dispatch: cfg.dispatch,
                max_sessions: cfg.max_sessions,
                record_events: collect_trace,
                ..FleetConfig::new(cfg.executors)
            },
        );
        let fleet_ref = &fleet;

        // shared shed bookkeeping: counted per reason, into the fleet
        // totals (→ telemetry), and as a ring sample
        let note_shed = |reason: ShedReason, latency_us: f64, model: usize| {
            shed_by_reason[reason_idx(reason)].fetch_add(1, Ordering::Relaxed);
            fleet_ref.record_shed();
            ring.push(SessionSample {
                t_us: fleet_ref.now_us(),
                latency_us,
                class: OutcomeClass::Shed,
                model: model as u8,
            });
        };
        let note_shed = &note_shed;

        // one merged fleet session for `members` (≥2) of zoo entry
        // `pick`: the group leader runs this on behalf of everyone — one
        // admission-queue entry, one submit over the pre-built union, one
        // SessionReport fanned back out into per-member latencies,
        // outcome classes, ring samples, and trace lanes. Resolves
        // `outstanding` once per member.
        let run_batch = |pick: usize, members: &[BatchMember]| {
            let z = &zoo[pick];
            let k = members.len();
            let bz = &z.batched[k - 2];
            debug_assert_eq!(bz.graph.len(), z.graph.len() * k);
            // the union's components run concurrently, so the batch
            // charges the sum of the members' planned peaks
            let bytes = z.peak_bytes * k as u64;
            // the most urgent member sets the batch's place in line
            let class = members.iter().map(|m| m.class).min().unwrap_or(1);
            let permit = match queue.try_admit(bytes) {
                Some(p) => p,
                None => {
                    admission_blocked.fetch_add(k as u64, Ordering::Relaxed);
                    let mut req = AdmitRequest::new(bytes).with_class(class);
                    if let Some(d) = deadline {
                        req = req.with_patience(d);
                    }
                    match queue.admit_request(req) {
                        Ok(p) => p,
                        Err(reason) => {
                            // the whole batch sheds: one counted shed per
                            // member, so conservation stays per-request
                            for m in members {
                                note_shed(reason, m.t0.elapsed().as_secs_f64() * 1e6, pick);
                            }
                            outstanding.fetch_sub(k, Ordering::SeqCst);
                            return;
                        }
                    }
                }
            };
            let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            max_in_flight.fetch_max(now, Ordering::SeqCst);
            let handle = if let Some(ws) = &bz.widths {
                fleet_ref.submit_moldable(
                    &bz.graph,
                    Arc::clone(&bz.levels),
                    Arc::clone(ws),
                    Arc::clone(&wide_work),
                    deadline,
                )
            } else if let Some(d) = deadline {
                fleet_ref.submit_with_deadline(&bz.graph, Arc::clone(&bz.levels), work_ref, d)
            } else {
                fleet_ref.submit(&bz.graph, Arc::clone(&bz.levels), work_ref)
            };
            let seq = handle.seq();
            let submit_us = handle.submitted_at_us();
            let outcome = handle.wait();
            in_flight.fetch_sub(1, Ordering::SeqCst);
            drop(permit);
            let lat_class = match &outcome {
                Ok(_) => 0,
                Err(SessionError::Cancelled) => 2,
                Err(SessionError::DeadlineExceeded) => 3,
                Err(_) => 1,
            };
            let glen = z.graph.len() as NodeId;
            for (mi, m) in members.iter().enumerate() {
                let lat = m.t0.elapsed().as_secs_f64() * 1e6;
                latencies.lock().unwrap().push(lat);
                by_class[lat_class].lock().unwrap().push(lat);
                ring.push(SessionSample {
                    t_us: fleet_ref.now_us(),
                    latency_us: lat,
                    class: CLASS_OUTCOMES[lat_class],
                    model: pick as u8,
                });
                if collect_trace {
                    let sampled = (m.index as u64) % cfg.trace_sample == 0;
                    let (cause, end_us, records) = match &outcome {
                        Ok(r) => (
                            "done",
                            submit_us + r.wall_us,
                            if sampled {
                                // the member's slice of the union: its
                                // component's contiguous id range, mapped
                                // back to model-local node ids
                                r.records
                                    .iter()
                                    .filter(|rec| rec.node / glen == mi as NodeId)
                                    .map(|rec| OpRecord {
                                        node: rec.node % glen,
                                        executor: rec.executor,
                                        start_us: rec.start_us,
                                        end_us: rec.end_us,
                                    })
                                    .collect()
                            } else {
                                Vec::new()
                            },
                        ),
                        Err(SessionError::Cancelled) => {
                            ("cancelled", fleet_ref.now_us(), Vec::new())
                        }
                        Err(SessionError::DeadlineExceeded) => {
                            ("deadline", fleet_ref.now_us(), Vec::new())
                        }
                        Err(SessionError::Stalled) => ("stalled", fleet_ref.now_us(), Vec::new()),
                        Err(SessionError::OpPanicked { .. }) => {
                            ("failed", fleet_ref.now_us(), Vec::new())
                        }
                        Err(SessionError::Shed { .. }) => ("shed", fleet_ref.now_us(), Vec::new()),
                    };
                    collected.lock().unwrap().push(CollectedSession {
                        zoo: pick,
                        seq,
                        member: mi,
                        of: k,
                        submit_us,
                        end_us,
                        outcome: cause.to_string(),
                        records,
                    });
                }
                if outcome.is_ok() {
                    completed_per_model[pick].fetch_add(1, Ordering::Relaxed);
                }
            }
            if let Ok(report) = &outcome {
                // fleet-level counters stay per fleet session, so the
                // per-session-sum == fleet-total partition stays exact
                session_dispatches.fetch_add(report.dispatches, Ordering::Relaxed);
                session_steals.fetch_add(report.steals, Ordering::Relaxed);
            }
            outstanding.fetch_sub(k, Ordering::SeqCst);
        };
        let run_batch = &run_batch;

        // the whole lifecycle of request `i`, shared by closed-loop
        // clients (which loop it) and open-loop request threads (one
        // call each); every request resolves `outstanding` exactly once
        // — here, or in run_batch when a batch leader resolves it
        let run_request = |i: usize, rng: &mut Rng| {
            // weighted model pick
            let mut draw = rng.f64() * total_weight;
            let mut pick = zoo.len() - 1;
            for (zi, z) in zoo.iter().enumerate() {
                if draw < z.weight {
                    pick = zi;
                    break;
                }
                draw -= z.weight;
            }
            let z = &zoo[pick];
            let plan = if cfg.fault_rate > 0.0 {
                FaultPlan::draw(rng, z.graph.len(), cfg.fault_rate, fault_delay_us)
            } else {
                FaultPlan::default()
            };
            // classes only exist (and only consume a draw) under the
            // priority policy, keeping FIFO/EDF rng streams unchanged
            let class = if cfg.admission == AdmissionPolicy::Priority {
                rng.below(3) as u8
            } else {
                1
            };
            let t0 = Instant::now();
            // batching gate: compatible waiting requests merge at the
            // admission frontier. Faulty requests never batch (a panic or
            // cancel must stay confined to its own request), and a zoo
            // entry whose union table was truncated by the session node
            // limit caps its own batch size.
            if batch_cap[pick] > 1 && !plan.is_faulty() {
                match batcher.join(pick, BatchMember { index: i, class, t0 }, batch_cap[pick]) {
                    BatchJoin::Follower => return, // the leader resolves us
                    BatchJoin::Leader(group) => {
                        let members = batcher.close(pick, &group);
                        batch_groups[members.len() - 1].fetch_add(1, Ordering::Relaxed);
                        if members.len() >= 2 {
                            batched_requests.fetch_add(members.len() as u64, Ordering::Relaxed);
                            run_batch(pick, &members);
                            return;
                        }
                        // the window expired with no joiner: fall through
                        // to the solo path (the wait already counts
                        // against t0, like any admission wait)
                    }
                }
            }
            // §5.1 admission: wait until the planned peak fits — for at
            // most the deadline patience when one is configured, bounced
            // early by the depth cap / wait predictor when those are on
            let permit = match queue.try_admit(z.peak_bytes) {
                Some(p) => p,
                None => {
                    admission_blocked.fetch_add(1, Ordering::Relaxed);
                    let mut req = AdmitRequest::new(z.peak_bytes).with_class(class);
                    if let Some(d) = deadline {
                        req = req.with_patience(d);
                    }
                    match queue.admit_request(req) {
                        Ok(p) => p,
                        Err(reason) => {
                            note_shed(reason, t0.elapsed().as_secs_f64() * 1e6, pick);
                            outstanding.fetch_sub(1, Ordering::SeqCst);
                            return;
                        }
                    }
                }
            };
            let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            max_in_flight.fetch_max(now, Ordering::SeqCst);
            let handle = if let Some(ws) = &z.widths {
                // moldable entry: gangs on the healthy path; faults wrap
                // the wide closure so the panic lands on a gang member
                let ww: Arc<dyn Fn(NodeId, u32, u32) + Send + Sync> = if plan.is_faulty() {
                    let inner = Arc::clone(&wide_work);
                    Arc::new(plan.clone().wrap_wide(move |n, rank, w| inner(n, rank, w)))
                } else {
                    Arc::clone(&wide_work)
                };
                fleet_ref.submit_moldable(&z.graph, Arc::clone(&z.levels), Arc::clone(ws), ww, deadline)
            } else if plan.is_faulty() {
                // faulty sessions own a wrapped closure; healthy
                // ones keep the borrowed zero-allocation path
                fleet_ref.submit_owned(
                    &z.graph,
                    Arc::clone(&z.levels),
                    Arc::new(plan.clone().wrap(work)),
                    deadline,
                )
            } else if let Some(d) = deadline {
                fleet_ref.submit_with_deadline(&z.graph, Arc::clone(&z.levels), work_ref, d)
            } else {
                fleet_ref.submit(&z.graph, Arc::clone(&z.levels), work_ref)
            };
            if let Some(after_us) = plan.cancel_after_us {
                std::thread::sleep(Duration::from_micros(after_us as u64));
                handle.cancel();
            }
            // wait() consumes the handle — grab the trace identity first
            let seq = handle.seq();
            let submit_us = handle.submitted_at_us();
            let outcome = handle.wait();
            in_flight.fetch_sub(1, Ordering::SeqCst);
            drop(permit);
            let lat = t0.elapsed().as_secs_f64() * 1e6;
            latencies.lock().unwrap().push(lat);
            let lat_class = match &outcome {
                Ok(_) => 0,
                Err(SessionError::Cancelled) => 2,
                Err(SessionError::DeadlineExceeded) => 3,
                Err(_) => 1,
            };
            by_class[lat_class].lock().unwrap().push(lat);
            ring.push(SessionSample {
                t_us: fleet_ref.now_us(),
                latency_us: lat,
                class: CLASS_OUTCOMES[lat_class],
                model: pick as u8,
            });
            if collect_trace {
                let sampled = (i as u64) % cfg.trace_sample == 0;
                let (cause, end_us, records) = match &outcome {
                    Ok(r) => (
                        "done",
                        submit_us + r.wall_us,
                        if sampled { r.records.clone() } else { Vec::new() },
                    ),
                    Err(SessionError::Cancelled) => ("cancelled", fleet_ref.now_us(), Vec::new()),
                    Err(SessionError::DeadlineExceeded) => {
                        ("deadline", fleet_ref.now_us(), Vec::new())
                    }
                    Err(SessionError::Stalled) => ("stalled", fleet_ref.now_us(), Vec::new()),
                    Err(SessionError::OpPanicked { .. }) => {
                        ("failed", fleet_ref.now_us(), Vec::new())
                    }
                    // sheds return before submission; a Shed terminal on a
                    // submitted session cannot happen, but stay total
                    Err(SessionError::Shed { .. }) => ("shed", fleet_ref.now_us(), Vec::new()),
                };
                collected.lock().unwrap().push(CollectedSession {
                    zoo: pick,
                    seq,
                    member: 0,
                    of: 1,
                    submit_us,
                    end_us,
                    outcome: cause.to_string(),
                    records,
                });
            }
            if let Ok(report) = outcome {
                completed_per_model[pick].fetch_add(1, Ordering::Relaxed);
                session_dispatches.fetch_add(report.dispatches, Ordering::Relaxed);
                session_steals.fetch_add(report.steals, Ordering::Relaxed);
            }
            outstanding.fetch_sub(1, Ordering::SeqCst);
        };
        let run_request = &run_request;

        // request threads live in a nested scope so they may borrow the
        // fleet — and are all joined before the fleet shuts down
        std::thread::scope(|reqs| {
            if let Some(every_ms) = cfg.telemetry_every_ms {
                let ring = &ring;
                let snapshots = &snapshots;
                let outstanding = &outstanding;
                let queue = &queue;
                let in_flight = &in_flight;
                reqs.spawn(move || {
                    let mut prev: Option<TelemetrySnapshot> = None;
                    loop {
                        // sleep in short slices so the monitor notices the
                        // run ending instead of overshooting by an interval
                        let mut slept_ms = 0u64;
                        while slept_ms < every_ms && outstanding.load(Ordering::SeqCst) > 0 {
                            let slice = (every_ms - slept_ms).min(20);
                            std::thread::sleep(Duration::from_millis(slice));
                            slept_ms += slice;
                        }
                        if outstanding.load(Ordering::SeqCst) == 0 {
                            return;
                        }
                        let snap = ring.snapshot(
                            fleet_ref.now_us(),
                            fleet_ref.totals(),
                            queue.waiting(),
                            in_flight.load(Ordering::SeqCst),
                            prev.as_ref(),
                        );
                        println!("{}", snap.render_line());
                        snapshots.lock().unwrap().push(snap.clone());
                        prev = Some(snap);
                    }
                });
            }
            if open_loop {
                // the dispatcher: replay the precomputed schedule on this
                // thread, one request thread per arrival — never waiting
                // for the fleet, that is the point of the open loop
                let cap = live_request_cap(cfg.max_sessions);
                for (i, &at_us) in schedule.iter().enumerate() {
                    let target = Duration::from_micros(at_us);
                    let elapsed = t_start.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                    if live_requests.load(Ordering::SeqCst) >= cap {
                        // thread-pressure backstop: reject instantly rather
                        // than spawning unboundedly many OS threads
                        note_shed(ShedReason::QueueFull, 0.0, 0);
                        outstanding.fetch_sub(1, Ordering::SeqCst);
                        continue;
                    }
                    live_requests.fetch_add(1, Ordering::SeqCst);
                    let live_requests = &live_requests;
                    reqs.spawn(move || {
                        // per-request rng: deterministic per (seed, i),
                        // independent of dispatch interleaving
                        let mut rng = Rng::new(cfg.seed ^ ((i as u64 + 1) << 17) ^ 0x0A77_1B07);
                        run_request(i, &mut rng);
                        live_requests.fetch_sub(1, Ordering::SeqCst);
                    });
                }
            } else {
                for c in 0..cfg.clients {
                    let next_request = &next_request;
                    let mut rng = Rng::new(cfg.seed ^ ((c as u64 + 1) << 40));
                    reqs.spawn(move || loop {
                        let i = next_request.fetch_add(1, Ordering::Relaxed);
                        if i >= cfg.requests {
                            return;
                        }
                        run_request(i, &mut rng);
                    });
                }
            }
        });
        // final snapshot: every run reports at least one, interval or not
        {
            let prev = snapshots.lock().unwrap().last().cloned();
            let snap =
                ring.snapshot(fleet.now_us(), fleet.totals(), queue.waiting(), 0, prev.as_ref());
            snapshots.lock().unwrap().push(snap);
        }
        let fleet_events = fleet.drain_events();
        // a faulty run reports its failures through the per-class counts;
        // the shutdown error carries the same totals snapshot
        let totals = match fleet.shutdown() {
            Ok(t) => t,
            Err(e) => e.totals,
        };
        (totals, fleet_events)
    });
    let wall_s = t_start.elapsed().as_secs_f64();

    if let Some(path) = &cfg.trace_path {
        let mut sessions = collected.into_inner().unwrap();
        sessions.sort_by_key(|s| (s.seq, s.member));
        let exports: Vec<SessionTraceExport<'_>> = sessions
            .iter()
            .map(|c| SessionTraceExport {
                // one lifecycle lane per *logical request*: members of a
                // merged session share a seq but get their own lane
                label: if c.of > 1 {
                    format!("session {}.{} ({})", c.seq, c.member, zoo[c.zoo].tag)
                } else {
                    format!("session {} ({})", c.seq, zoo[c.zoo].tag)
                },
                graph: &zoo[c.zoo].graph,
                levels: Some(&zoo[c.zoo].levels[..]),
                records: &c.records,
                start_us: c.submit_us,
                end_us: c.end_us,
                outcome: c.outcome.clone(),
            })
            .collect();
        let text = export_chrome_trace(&exports, &fleet_events, cfg.executors);
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        std::fs::write(path, text)
            .unwrap_or_else(|e| panic!("failed to write serve trace to {path}: {e}"));
    }

    let latencies = latencies.into_inner().unwrap();
    let class_samples: Vec<Vec<f64>> =
        by_class.into_iter().map(|m| m.into_inner().unwrap()).collect();
    let completed = class_samples[0].len();
    let shed: u64 = shed_by_reason.iter().map(|n| n.load(Ordering::SeqCst)).sum();
    debug_assert_eq!(shed, totals.sessions_shed, "every shed is recorded on the fleet");
    let batched = batched_requests.load(Ordering::SeqCst);
    ServeReport {
        dispatch: cfg.dispatch,
        offered_rps: cfg.arrival.offered_rps(),
        offered: cfg.requests,
        completed,
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        latency_us: if latencies.is_empty() {
            Summary::from_samples(&[0.0])
        } else {
            Summary::from_samples(&latencies)
        },
        per_model: zoo
            .iter()
            .zip(&completed_per_model)
            .map(|(z, n)| (z.tag.clone(), n.load(Ordering::SeqCst), z.peak_bytes))
            .collect(),
        totals,
        session_dispatches: session_dispatches.load(Ordering::SeqCst),
        session_steals: session_steals.load(Ordering::SeqCst),
        max_in_flight: max_in_flight.load(Ordering::SeqCst),
        admission_blocked: admission_blocked.load(Ordering::SeqCst),
        // request-level counts from the per-request class samples, NOT
        // the fleet's per-session counters: one batched session's
        // terminal must count once per member. Without batching the two
        // are identical (one request per session).
        failed: class_samples[1].len() as u64,
        cancelled: class_samples[2].len() as u64,
        deadline_missed: class_samples[3].len() as u64,
        shed,
        shed_reasons: REASON_NAMES
            .iter()
            .zip(&shed_by_reason)
            .filter_map(|(name, n)| {
                let n = n.load(Ordering::SeqCst);
                (n > 0).then(|| (name.to_string(), n))
            })
            .collect(),
        latency_by_class: CLASSES
            .iter()
            .zip(&class_samples)
            .filter_map(|(c, s)| Summary::from_samples_opt(s).map(|sum| (c.to_string(), sum)))
            .collect(),
        snapshots: snapshots.into_inner().unwrap(),
        batched_fraction: batched as f64 / cfg.requests as f64,
        batch_sizes: batch_groups
            .iter()
            .enumerate()
            .filter_map(|(i, n)| {
                let n = n.load(Ordering::SeqCst);
                (n > 0).then_some((i + 1, n))
            })
            .collect(),
    }
}

/// One load point of an offered-load sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub offered_rps: f64,
    pub report: ServeReport,
}

/// Outcome of [`serve_sweep`]: per-point reports plus the knee.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub points: Vec<SweepPoint>,
    /// Highest offered load that still completed ≥90 % of its offered
    /// requests with <5 % shed — `None` when every point in the sweep
    /// was saturated.
    pub knee_rps: Option<f64>,
}

impl SweepReport {
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "== offered-load sweep ({} points) ==", self.points.len());
        for p in &self.points {
            let r = &p.report;
            let _ = writeln!(
                out,
                "rps {:9.1} → achieved {:9.1}  p50 {}  p99 {}  shed {:5.1}%",
                p.offered_rps,
                r.throughput_rps,
                crate::util::fmt_us(r.latency_us.p50),
                crate::util::fmt_us(r.latency_us.p99),
                r.shed_fraction() * 100.0,
            );
        }
        match self.knee_rps {
            Some(rps) => {
                let _ = writeln!(
                    out,
                    "knee ≈ {rps:.1} rps (highest offered load completing ≥90% with <5% shed)"
                );
            }
            None => {
                let _ = writeln!(out, "no knee within the sweep: every load point saturated");
            }
        }
        out
    }
}

/// Replay `cfg` at each offered load in `rps_points` (a fresh fleet per
/// point) and locate the latency-vs-throughput knee. Closed-loop configs
/// are promoted to Poisson arrivals; bursty configs keep their burst
/// shape at each swept rate.
pub fn serve_sweep(cfg: &ServeConfig, rps_points: &[f64]) -> SweepReport {
    assert!(!rps_points.is_empty(), "sweep needs at least one load point");
    let points: Vec<SweepPoint> = rps_points
        .iter()
        .map(|&rps| {
            assert!(rps.is_finite() && rps > 0.0, "offered load must be positive");
            let mut point_cfg = cfg.clone();
            point_cfg.arrival = match cfg.arrival {
                Arrival::Bursty { .. } => Arrival::Bursty { rps },
                _ => Arrival::Poisson { rps },
            };
            SweepPoint { offered_rps: rps, report: serve(&point_cfg) }
        })
        .collect();
    let knee_rps = points
        .iter()
        .filter(|p| p.report.shed_fraction() < 0.05 && p.report.completed_fraction() >= 0.9)
        .map(|p| p.offered_rps)
        .fold(None, |best: Option<f64>, rps| Some(best.map_or(rps, |b| b.max(rps))));
    SweepReport { points, knee_rps }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(mode: DispatchMode) -> ServeConfig {
        ServeConfig {
            executors: 2,
            dispatch: mode,
            clients: 2,
            requests: 12,
            mix: vec![(ModelKind::Mlp, 1.0)],
            ..ServeConfig::default()
        }
    }

    #[test]
    fn closed_loop_completes_every_request_in_both_modes() {
        for mode in DispatchMode::ALL {
            let report = serve(&quick(mode));
            assert_eq!(report.completed, 12, "{}", mode.name());
            assert_eq!(report.totals.sessions_completed, 12, "{}", mode.name());
            assert_eq!(report.latency_us.n, 12, "{}", mode.name());
            assert!(report.throughput_rps > 0.0, "{}", mode.name());
            assert_eq!(report.offered_rps, None, "{}", mode.name());
            // per-session metric partition: sums match the fleet totals
            assert_eq!(report.session_dispatches, report.totals.dispatches, "{}", mode.name());
            assert!(report.session_steals <= report.totals.steals, "{}", mode.name());
            let per_model_total: u64 = report.per_model.iter().map(|(_, n, _)| n).sum();
            assert_eq!(per_model_total, 12, "{}", mode.name());
            let text = report.render();
            assert!(text.contains("sessions/s"), "{text}");
        }
    }

    #[test]
    fn tight_budget_serializes_but_still_completes() {
        // a budget of one byte forces every session to run alone: the
        // closed loop must degrade to serial admission, not deadlock
        let cfg = ServeConfig { budget_bytes: 1, ..quick(DispatchMode::Decentralized) };
        let report = serve(&cfg);
        assert_eq!(report.completed, 12);
        assert_eq!(report.max_in_flight, 1, "one-byte budget ⇒ strictly serial sessions");
        // (whether a client ever *observed* the full budget is a scheduling
        // race; the deterministic blocking proof lives in the SessionQueue
        // unit tests and tests/serve_sessions.rs)
    }

    #[test]
    fn seeded_faults_are_reported_and_conserved() {
        for mode in DispatchMode::ALL {
            let cfg = ServeConfig {
                executors: 2,
                dispatch: mode,
                clients: 2,
                requests: 40,
                mix: vec![(ModelKind::Mlp, 1.0)],
                fault_rate: 1.0,
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            // every request is accounted for exactly once
            assert_eq!(report.accounted(), 40, "{}: {report:?}", mode.name());
            // rate 1.0 over 40 draws: a panic plan is (overwhelmingly,
            // and for seed 42 deterministically) among them, and every
            // panic plan fails its session
            assert!(report.failed > 0, "{}", mode.name());
            // the fleet survived every fault: completions the counters
            // agree on, plus a latency sample for every non-shed request
            assert_eq!(report.totals.sessions_completed, report.completed as u64, "{}", mode.name());
            let class_n: u64 = report.latency_by_class.iter().map(|(_, s)| s.n as u64).sum();
            assert_eq!(class_n + report.shed, 40, "{}", mode.name());
            let text = report.render();
            assert!(text.contains("failed"), "{text}");
        }
    }

    #[test]
    fn moldable_serve_forms_gangs_and_conserves() {
        // one client against four executors leaves three peers idle at
        // every pop — plenty of recruits for the molded gemm gangs
        let mut plan = WidthPlan::uniform(1);
        plan.set(crate::graph::op::OpClass::Gemm, 2);
        for mode in DispatchMode::ALL {
            let cfg = ServeConfig {
                executors: 4,
                dispatch: mode,
                clients: 1,
                requests: 12,
                mix: vec![(ModelKind::Mlp, 1.0)],
                op_spin_us: 20.0,
                width_plan: Some(plan.clone()),
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            assert_eq!(report.completed, 12, "{}", mode.name());
            assert_eq!(report.accounted(), 12, "{}", mode.name());
            assert!(
                report.totals.gangs_formed > 0,
                "{}: molded mlp gemms never formed a gang: {:?}",
                mode.name(),
                report.totals
            );
            assert!(report.totals.gang_recruits >= report.totals.gangs_formed, "{}", mode.name());
            let text = report.render();
            assert!(text.contains("gangs formed"), "{text}");
        }
    }

    #[test]
    fn moldable_serve_survives_gang_member_faults() {
        // every request draws a fault plan; panics land on the gang's
        // highest rank (FaultPlan::wrap_wide), exercising the member →
        // fail_session confinement path under real serve traffic
        let mut plan = WidthPlan::uniform(1);
        plan.set(crate::graph::op::OpClass::Gemm, 2);
        for mode in DispatchMode::ALL {
            let cfg = ServeConfig {
                executors: 4,
                dispatch: mode,
                clients: 2,
                requests: 24,
                mix: vec![(ModelKind::Mlp, 1.0)],
                op_spin_us: 10.0,
                fault_rate: 1.0,
                width_plan: Some(plan.clone()),
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            assert_eq!(report.accounted(), 24, "{}: {report:?}", mode.name());
            assert!(report.failed > 0, "{}: seed 42 must draw a panic plan", mode.name());
            assert!(report.completed > 0, "{}: the fleet must outlive the faults", mode.name());
            assert_eq!(
                report.totals.sessions_completed,
                report.completed as u64,
                "{}",
                mode.name()
            );
        }
    }

    #[test]
    fn uniform_one_width_plan_is_invisible() {
        // a plan that resolves every node to width 1 must leave the run
        // on the pre-moldable paths: no gangs, same counters as None
        let cfg = ServeConfig {
            width_plan: Some(WidthPlan::uniform(1)),
            ..quick(DispatchMode::Decentralized)
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 12);
        assert_eq!(report.totals.gangs_formed, 0, "{:?}", report.totals);
        assert_eq!(report.totals.gang_recruits, 0);
        assert!(!report.render().contains("gangs formed"));
    }

    #[test]
    fn tight_deadline_misses_are_counted() {
        let cfg = ServeConfig {
            executors: 2,
            clients: 2,
            requests: 8,
            mix: vec![(ModelKind::Mlp, 1.0)],
            op_spin_us: 50.0,
            deadline_us: Some(1),
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        // a 1µs deadline over 50µs ops: no mlp session can finish in time,
        // and a request that cannot even get admitted in time is shed
        assert_eq!(report.deadline_missed + report.shed, 8, "{report:?}");
        assert_eq!(report.completed, 0, "{report:?}");
    }

    #[test]
    fn mixed_zoo_spreads_requests_across_models() {
        let cfg = ServeConfig {
            executors: 2,
            clients: 3,
            requests: 24,
            mix: vec![(ModelKind::Mlp, 1.0), (ModelKind::PathNet, 1.0)],
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 24);
        // with an even weighting over 24 requests, both models must appear
        let counts: Vec<u64> = report.per_model.iter().map(|(_, n, _)| *n).collect();
        assert_eq!(counts.iter().sum::<u64>(), 24);
        assert!(counts.iter().all(|&n| n > 0), "both mix entries must be exercised: {counts:?}");
    }

    #[test]
    fn degenerate_runs_keep_latency_summaries_finite() {
        // a single request: one sample per summary, every percentile finite
        let cfg = ServeConfig {
            executors: 2,
            clients: 1,
            requests: 1,
            mix: vec![(ModelKind::Mlp, 1.0)],
            telemetry_ring: 4,
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 1);
        assert!(report.latency_us.p50.is_finite() && report.latency_us.p99.is_finite());
        assert_eq!(report.latency_by_class.len(), 1, "only the ok class has samples");
        for (class, s) in &report.latency_by_class {
            assert_eq!(s.n, 1, "{class}");
            assert!(s.p50.is_finite() && s.p99.is_finite(), "{class}");
            assert_eq!(s.p50, s.p99, "single sample: every percentile is it");
        }
        // the final telemetry snapshot is always present and finite
        let snap = report.snapshots.last().expect("final snapshot");
        assert_eq!(snap.total_sessions, 1);
        assert!(snap.rps.is_finite() && snap.steal_rate.is_finite());
        for (class, s) in &snap.per_class {
            assert!(s.p50.is_finite() && s.p99.is_finite(), "{}", class.name());
        }
        let text = report.render();
        assert!(text.contains("telemetry"), "{text}");
    }

    #[test]
    fn trace_export_covers_every_session_and_validates() {
        let path = std::env::temp_dir()
            .join(format!("graphi-serve-trace-{}.json", std::process::id()));
        let cfg = ServeConfig {
            executors: 2,
            clients: 2,
            requests: 8,
            mix: vec![(ModelKind::Mlp, 1.0)],
            trace_path: Some(path.to_string_lossy().into_owned()),
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 8);
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let stats = crate::engine::validate_chrome_trace(&text).unwrap();
        assert_eq!(stats.processes, 1 + 8, "the fleet plus one process per session");
        assert!(stats.spans > 0);
        assert!(stats.instant_names.contains("admitted"), "{:?}", stats.instant_names);
        assert!(stats.instant_names.contains("done"), "{:?}", stats.instant_names);
    }

    #[test]
    fn arrival_schedules_are_deterministic_sorted_and_load_scaled() {
        let a = arrival_offsets_us(Arrival::Poisson { rps: 1000.0 }, 200, 7);
        let b = arrival_offsets_us(Arrival::Poisson { rps: 1000.0 }, 200, 7);
        assert_eq!(a, b, "same seed ⇒ same schedule");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrival offsets are nondecreasing");
        // 200 arrivals at 1000/s: the span concentrates near 200ms
        let span_us = *a.last().unwrap() as f64;
        assert!((100_000.0..400_000.0).contains(&span_us), "span {span_us}µs");
        // doubling the offered load roughly halves the span
        let c = arrival_offsets_us(Arrival::Poisson { rps: 2000.0 }, 200, 7);
        let ratio = span_us / (*c.last().unwrap() as f64);
        assert!((1.3..3.0).contains(&ratio), "load scaling off: ratio {ratio}");
        // bursty averages the same long-run rate but clusters: the
        // minimum gap is (much) smaller than the mean gap
        let d = arrival_offsets_us(Arrival::Bursty { rps: 1000.0 }, 200, 7);
        assert!(d.windows(2).all(|w| w[0] <= w[1]));
        let span_d = *d.last().unwrap() as f64;
        assert!((100_000.0..600_000.0).contains(&span_d), "bursty span {span_d}µs");
        let gaps: Vec<u64> = d.windows(2).map(|w| w[1] - w[0]).collect();
        let mean_gap = gaps.iter().sum::<u64>() as f64 / gaps.len() as f64;
        let min_gap = *gaps.iter().min().unwrap() as f64;
        assert!(min_gap < mean_gap / 2.0, "bursty arrivals must cluster");
    }

    #[test]
    fn arrival_offsets_round_to_the_nearest_microsecond() {
        // reconstruct the exact f64 schedule in lockstep with the same
        // rng stream and check every integer offset is the *nearest* µs:
        // truncation (`as u64`) drags each offset toward zero by up to a
        // full µs, which at high rps collapses sub-µs gaps and skews the
        // realized inter-arrival spacing
        let check = |arrival: Arrival, seed: u64, rel_tol: f64| {
            let n = 2_000usize;
            let offsets = arrival_offsets_us(arrival, n, seed);
            assert!(
                offsets.windows(2).all(|w| w[0] <= w[1]),
                "{arrival:?}: offsets must be non-decreasing"
            );
            let mut rng = Rng::new(seed ^ 0xA881_7A1E);
            let mut t = 0.0f64;
            let exact: Vec<f64> = match arrival {
                Arrival::Closed => unreachable!(),
                Arrival::Poisson { rps } => (0..n)
                    .map(|_| {
                        t += rng.exponential(1e6 / rps);
                        t
                    })
                    .collect(),
                Arrival::Bursty { rps } => {
                    let mut on_left = rng.exponential(BURST_ON_US);
                    (0..n)
                        .map(|_| {
                            let mut gap = rng.exponential(1e6 / (BURST_FACTOR * rps));
                            while gap > on_left {
                                gap -= on_left;
                                t += on_left + rng.exponential((BURST_FACTOR - 1.0) * BURST_ON_US);
                                on_left = rng.exponential(BURST_ON_US);
                            }
                            on_left -= gap;
                            t += gap;
                            t
                        })
                        .collect()
                }
            };
            for (i, (&o, &e)) in offsets.iter().zip(&exact).enumerate() {
                assert!(
                    (o as f64 - e).abs() <= 0.5,
                    "{arrival:?} offset {i}: got {o}, exact {e:.3} — truncated, not rounded"
                );
            }
            // the realized mean gap tracks the offered load
            let rps = arrival.offered_rps().unwrap();
            let mean_gap = *offsets.last().unwrap() as f64 / n as f64;
            let want = 1e6 / rps;
            assert!(
                (mean_gap - want).abs() < want * rel_tol,
                "{arrival:?}: mean gap {mean_gap:.3}µs, want ≈{want:.3}µs"
            );
        };
        // 250k rps ⇒ 4µs mean gaps: sub-µs rounding error is material here
        check(Arrival::Poisson { rps: 250_000.0 }, 7, 0.15);
        // bursty needs a lower rate so 2k arrivals span many on/off
        // windows (≈80 arrivals per window here) — the long-run average
        // is noisier, hence the wider tolerance
        check(Arrival::Bursty { rps: 2_000.0 }, 7, 0.40);
    }

    #[test]
    fn open_loop_overload_sheds_and_conserves_in_both_modes() {
        // ≥2× overload: a one-byte budget serializes sessions and the
        // offered load is far past the serial service rate, with a 2ms
        // deadline as admission patience — the run must terminate with
        // every request in exactly one class and nonzero sheds
        for mode in DispatchMode::ALL {
            let cfg = ServeConfig {
                executors: 2,
                dispatch: mode,
                clients: 1,
                requests: 60,
                arrival: Arrival::Poisson { rps: 4000.0 },
                mix: vec![(ModelKind::Mlp, 1.0)],
                budget_bytes: 1,
                op_spin_us: 20.0,
                deadline_us: Some(2_000),
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            assert_eq!(report.accounted(), 60, "{}: {report:?}", mode.name());
            assert!(report.shed > 0, "{}: overload must shed: {report:?}", mode.name());
            assert!(!report.shed_reasons.is_empty(), "{}", mode.name());
            assert_eq!(report.offered_rps, Some(4000.0), "{}", mode.name());
            let text = report.render();
            assert!(text.contains("open loop"), "{text}");
            assert!(text.contains("shed by reason"), "{text}");
        }
    }

    #[test]
    fn open_loop_bursty_and_policies_account_every_request() {
        // a comfortable load point: bursty arrivals under each admission
        // policy complete cleanly and conserve the outcome classes
        for policy in AdmissionPolicy::ALL {
            let cfg = ServeConfig {
                executors: 2,
                clients: 1,
                requests: 24,
                arrival: Arrival::Bursty { rps: 2000.0 },
                admission: policy,
                mix: vec![(ModelKind::Mlp, 1.0)],
                deadline_us: Some(2_000_000),
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            assert_eq!(report.accounted(), 24, "{}: {report:?}", policy.name());
            assert!(report.completed > 0, "{}: {report:?}", policy.name());
        }
    }

    #[test]
    fn depth_cap_sheds_queue_full_under_a_flood() {
        // everything arrives at once against a serial budget with a
        // 2-deep line: most requests must bounce as queue_full
        let cfg = ServeConfig {
            executors: 2,
            clients: 1,
            requests: 20,
            arrival: Arrival::Poisson { rps: 1e9 },
            queue_depth: Some(2),
            mix: vec![(ModelKind::Mlp, 1.0)],
            budget_bytes: 1,
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.accounted(), 20, "{report:?}");
        assert!(report.shed > 0, "{report:?}");
        assert!(
            report.shed_reasons.iter().any(|(r, n)| r == "queue_full" && *n > 0),
            "{report:?}"
        );
        // nobody waits forever: whoever got in line (≤ depth) ran
        assert_eq!(report.completed as u64 + report.shed, 20, "{report:?}");
    }

    #[test]
    fn sweep_locates_the_knee_between_a_comfortable_and_a_saturated_point() {
        let cfg = ServeConfig {
            executors: 2,
            clients: 1,
            requests: 20,
            queue_depth: Some(2),
            mix: vec![(ModelKind::Mlp, 1.0)],
            budget_bytes: 1,
            ..ServeConfig::default()
        };
        // 200 rps leaves ~5ms between serial sub-ms sessions: no queue,
        // no shed. 1e8 rps floods the 2-deep line instantly.
        let sweep = serve_sweep(&cfg, &[200.0, 1e8]);
        assert_eq!(sweep.points.len(), 2);
        let low = &sweep.points[0].report;
        let high = &sweep.points[1].report;
        assert_eq!(low.accounted(), 20, "{low:?}");
        assert_eq!(high.accounted(), 20, "{high:?}");
        assert!(high.shed_fraction() > 0.05, "flood must saturate: {high:?}");
        assert_eq!(sweep.knee_rps, Some(200.0), "low {low:?} high {high:?}");
        let text = sweep.render();
        assert!(text.contains("knee"), "{text}");
    }

    #[test]
    fn trace_sampling_bounds_op_spans_but_keeps_every_lifecycle() {
        let span_count = |sample: u64, tag: &str| {
            let path = std::env::temp_dir()
                .join(format!("graphi-serve-sample-{}-{tag}.json", std::process::id()));
            let cfg = ServeConfig {
                executors: 2,
                clients: 2,
                requests: 8,
                mix: vec![(ModelKind::Mlp, 1.0)],
                trace_path: Some(path.to_string_lossy().into_owned()),
                trace_sample: sample,
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            assert_eq!(report.completed, 8);
            let text = std::fs::read_to_string(&path).unwrap();
            let _ = std::fs::remove_file(&path);
            let stats = crate::engine::validate_chrome_trace(&text).unwrap();
            // sampling never hides a session: every lifecycle is present
            assert_eq!(stats.processes, 1 + 8, "sample={sample}");
            assert!(stats.instant_names.contains("admitted"), "sample={sample}");
            assert!(stats.instant_names.contains("done"), "sample={sample}");
            stats.spans
        };
        let full = span_count(1, "full");
        let quarter = span_count(4, "quarter");
        // 8 identical mlp sessions: sampling 1-in-4 keeps exactly 2
        // sessions' worth of op spans
        assert!(full > 0 && quarter > 0);
        assert_eq!(quarter * 4, full, "full {full} quarter {quarter}");
    }

    #[test]
    fn open_loop_batching_merges_conserves_and_reports() {
        // 40 arrivals 20µs apart against a 5ms batch window: groups must
        // form, and the request-level ledger must stay exact even though
        // the fleet ran fewer sessions than requests
        for mode in DispatchMode::ALL {
            let cfg = ServeConfig {
                executors: 2,
                dispatch: mode,
                clients: 1,
                requests: 40,
                arrival: Arrival::Poisson { rps: 50_000.0 },
                mix: vec![(ModelKind::Mlp, 1.0)],
                max_batch: 4,
                batch_window_us: 5_000,
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            assert_eq!(report.accounted(), 40, "{}: {report:?}", mode.name());
            assert_eq!(report.offered, 40, "{}", mode.name());
            assert_eq!(report.completed, 40, "{}: comfortable load", mode.name());
            assert_eq!(report.latency_us.n, 40, "{}: one latency per request", mode.name());
            assert!(report.batched_fraction > 0.0, "{}: {report:?}", mode.name());
            assert!(!report.batch_sizes.is_empty(), "{}", mode.name());
            // the histogram never accounts for more requests than offered
            let grouped: u64 = report.batch_sizes.iter().map(|(k, n)| *k as u64 * n).sum();
            assert!(grouped <= 40, "{}: {report:?}", mode.name());
            // merging happened: strictly fewer fleet sessions than requests
            assert!(
                report.totals.sessions_completed < report.completed as u64,
                "{}: {report:?}",
                mode.name()
            );
            let per_model_total: u64 = report.per_model.iter().map(|(_, n, _)| n).sum();
            assert_eq!(per_model_total, 40, "{}", mode.name());
            let text = report.render();
            assert!(text.contains("batching: "), "{text}");
            assert!(text.contains("accounted: 40/40"), "{text}");
        }
    }

    #[test]
    fn batched_overload_sheds_whole_groups_and_conserves() {
        // overload against a serial budget with batching on: sheds now
        // happen per *batch* inside the queue but must still be counted
        // per member, keeping the 5-class request ledger exact
        let cfg = ServeConfig {
            executors: 2,
            clients: 1,
            requests: 60,
            arrival: Arrival::Poisson { rps: 4000.0 },
            mix: vec![(ModelKind::Mlp, 1.0)],
            budget_bytes: 1,
            op_spin_us: 20.0,
            deadline_us: Some(2_000),
            max_batch: 4,
            batch_window_us: 500,
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.accounted(), 60, "{report:?}");
        assert!(report.shed > 0, "{report:?}");
        let text = report.render();
        assert!(text.contains("accounted: 60/60"), "{text}");
    }

    #[test]
    #[should_panic(expected = "open-loop arrival")]
    fn batching_rejects_closed_loop_arrivals() {
        let cfg = ServeConfig { max_batch: 2, ..quick(DispatchMode::Decentralized) };
        serve(&cfg);
    }

    #[test]
    fn batched_trace_keeps_one_lane_per_logical_request() {
        let path = std::env::temp_dir()
            .join(format!("graphi-serve-batch-trace-{}.json", std::process::id()));
        let cfg = ServeConfig {
            executors: 2,
            clients: 1,
            requests: 12,
            arrival: Arrival::Poisson { rps: 50_000.0 },
            mix: vec![(ModelKind::Mlp, 1.0)],
            max_batch: 3,
            batch_window_us: 5_000,
            trace_path: Some(path.to_string_lossy().into_owned()),
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 12);
        assert!(report.batched_fraction > 0.0, "{report:?}");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let stats = crate::engine::validate_chrome_trace(&text).unwrap();
        // one lifecycle lane per *logical request*, merged or not
        assert_eq!(stats.processes, 1 + 12, "{stats:?}");
        assert!(stats.instant_names.contains("done"), "{:?}", stats.instant_names);
        // every request is sampled and a member's lane carries exactly
        // its own component slice of the union, so op spans divide
        // evenly across the 12 identical mlp requests
        assert!(stats.spans > 0);
        assert_eq!(stats.spans % 12, 0, "{stats:?}");
    }

    #[test]
    fn unsampled_sessions_keep_their_terminal_causes() {
        let path = std::env::temp_dir()
            .join(format!("graphi-serve-causes-{}.json", std::process::id()));
        let cfg = ServeConfig {
            executors: 2,
            clients: 2,
            requests: 20,
            mix: vec![(ModelKind::Mlp, 1.0)],
            fault_rate: 1.0,
            trace_path: Some(path.to_string_lossy().into_owned()),
            // only request 0 is sampled: every fault cause below comes
            // from an unsampled session's lifecycle record
            trace_sample: 1000,
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert!(report.failed > 0, "{report:?}");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let stats = crate::engine::validate_chrome_trace(&text).unwrap();
        assert_eq!(stats.processes as u64, 1 + report.accounted() - report.shed);
        assert!(stats.instant_names.contains("failed"), "{:?}", stats.instant_names);
    }
}
