//! Closed-loop multi-model serving on one persistent executor fleet — the
//! engine behind `graphi serve`.
//!
//! A fixed pool of client threads replays a weighted model mix
//! (lstm / mlp / googlenet / pathnet by default) against a single
//! [`Fleet`]: each client picks a model, waits for §5.1 **memory
//! admission** ([`SessionQueue`], budgeted on the model's planned peak
//! arena footprint), submits the graph as a session, and blocks on the
//! session's quiescence before issuing its next request — a classic
//! closed-loop generator, so offered load ≈ `clients / mean latency` and
//! the fleet is never swamped beyond the admission budget.
//!
//! The report carries throughput, p50/p99 session latency, the fleet's
//! counter totals, and the per-session counter sums — the latter so the
//! metric partition (Σ per-session ≤ fleet totals) stays observable from
//! the CLI, not just from the differential tests.
//!
//! Two observability taps ride on the loop (both off by default):
//! [`ServeConfig::trace_path`] collects every session's op records plus
//! the fleet's steal/park events and writes one Chrome/Perfetto trace
//! with a pid per session, and [`ServeConfig::telemetry_every_ms`] prints
//! periodic aggregate snapshots from a bounded [`TelemetryRing`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::engine::trace::{export_chrome_trace, OpRecord, SessionTraceExport};
use crate::engine::DispatchMode;
use crate::graph::{levels as cp_levels, plan_memory, Graph, NodeId};
use crate::models::{self, ModelKind, ModelSize};
use crate::runtime::fleet::{Fleet, FleetConfig, FleetTotals, SessionError, SessionQueue};
use crate::runtime::telemetry::{OutcomeClass, SessionSample, TelemetryRing, TelemetrySnapshot};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::testkit::FaultPlan;

/// One serve experiment.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Executor threads in the (single, shared) fleet.
    pub executors: usize,
    /// Fleet dispatch architecture for this run.
    pub dispatch: DispatchMode,
    /// Closed-loop client threads (concurrent sessions ≤ this).
    pub clients: usize,
    /// Total sessions to execute.
    pub requests: usize,
    /// Weighted model mix (weights need not sum to 1).
    pub mix: Vec<(ModelKind, f64)>,
    pub size: ModelSize,
    /// Serve training graphs instead of forward-only inference graphs.
    pub training: bool,
    /// §5.1 admission budget over planned peak arena footprints.
    pub budget_bytes: u64,
    /// Fleet session-slot cap.
    pub max_sessions: usize,
    /// Busy-spin per op, µs (0 ⇒ scheduling-only, the dispatch-throughput
    /// regime the paper's small-op argument is about).
    pub op_spin_us: f64,
    /// Probability a request draws a fault plan (op panic / op delay /
    /// client cancel), split evenly between the three kinds; seeded, so a
    /// given `(seed, fault_rate)` replays the same fault schedule per
    /// client. 0 keeps the zero-allocation borrowed-closure hot path.
    pub fault_rate: f64,
    /// Per-session deadline, µs. Sessions past it terminate with
    /// [`SessionError::DeadlineExceeded`]; admission waits are bounded by
    /// the same patience and time-outs are **shed** (counted, not run).
    pub deadline_us: Option<u64>,
    /// Write a per-session Chrome/Perfetto trace of the whole run here
    /// (turns on fleet event recording and session record collection).
    pub trace_path: Option<String>,
    /// Print one aggregate telemetry line every this-many milliseconds
    /// while the run is live. The final snapshot is collected either way.
    pub telemetry_every_ms: Option<u64>,
    /// Capacity of the bounded ring of recent session samples that
    /// telemetry snapshots aggregate over.
    pub telemetry_ring: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            executors: 4,
            dispatch: DispatchMode::Decentralized,
            clients: 4,
            requests: 200,
            mix: vec![
                (ModelKind::Lstm, 1.0),
                (ModelKind::Mlp, 1.0),
                (ModelKind::GoogleNet, 1.0),
                (ModelKind::PathNet, 1.0),
            ],
            size: ModelSize::Small,
            training: false,
            // §7.1: the machine's 16 GB MCDRAM is the natural budget
            budget_bytes: 16 << 30,
            max_sessions: 32,
            op_spin_us: 0.0,
            fault_rate: 0.0,
            deadline_us: None,
            trace_path: None,
            telemetry_every_ms: None,
            telemetry_ring: 1024,
            seed: 42,
        }
    }
}

/// Outcome of one [`serve`] run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub dispatch: DispatchMode,
    pub completed: usize,
    pub wall_s: f64,
    /// Sessions per second over the whole run.
    pub throughput_rps: f64,
    /// Session latency summary (admission wait + execution), µs.
    pub latency_us: Summary,
    /// `(model tag, sessions completed, planned peak bytes)` per mix entry.
    pub per_model: Vec<(String, u64, u64)>,
    /// Fleet-lifetime counter totals.
    pub totals: FleetTotals,
    /// Σ of per-session dispatch counters (must equal the fleet total).
    pub session_dispatches: u64,
    /// Σ of per-session steal counters (≤ the fleet total).
    pub session_steals: u64,
    /// Peak concurrently-in-flight sessions observed.
    pub max_in_flight: usize,
    /// Requests that blocked in admission before fitting the budget.
    pub admission_blocked: u64,
    /// Sessions terminated by an op panic ([`SessionError::OpPanicked`]).
    pub failed: u64,
    /// Sessions terminated by client cancel ([`SessionError::Cancelled`]).
    pub cancelled: u64,
    /// Sessions terminated past their deadline
    /// ([`SessionError::DeadlineExceeded`]).
    pub deadline_missed: u64,
    /// Requests shed at admission: the memory budget did not free up
    /// within the deadline patience, so the session was never submitted.
    pub shed: u64,
    /// Latency summaries split by outcome class (`ok` / `failed` /
    /// `cancelled` / `deadline`); only classes with ≥1 sample appear.
    pub latency_by_class: Vec<(String, Summary)>,
    /// Telemetry snapshots collected over the run: one per
    /// [`ServeConfig::telemetry_every_ms`] interval plus always one final
    /// snapshot, so this is never empty.
    pub snapshots: Vec<TelemetrySnapshot>,
}

impl ServeReport {
    /// One-screen human-readable summary.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "== serve ({} dispatch) ==", self.dispatch.name());
        let _ = writeln!(
            out,
            "{} sessions in {:.2}s  →  {:.1} sessions/s",
            self.completed, self.wall_s, self.throughput_rps
        );
        let _ = writeln!(
            out,
            "session latency: p50 {}  p99 {}  max {}",
            crate::util::fmt_us(self.latency_us.p50),
            crate::util::fmt_us(self.latency_us.p99),
            crate::util::fmt_us(self.latency_us.max),
        );
        for (tag, n, bytes) in &self.per_model {
            let _ = writeln!(
                out,
                "  {tag:12} {n:6} sessions  (planned peak {})",
                crate::util::fmt_si(*bytes as f64)
            );
        }
        let _ = writeln!(
            out,
            "fleet: {} dispatches  {} steals ({} cross-domain)  {} parks  | per-session sums: {} dispatches, {} steals",
            self.totals.dispatches,
            self.totals.steals,
            self.totals.cross_domain_steals,
            self.totals.parks,
            self.session_dispatches,
            self.session_steals,
        );
        let _ = writeln!(
            out,
            "concurrency: ≤{} sessions in flight  |  admission: {} requests waited on the memory budget",
            self.max_in_flight, self.admission_blocked
        );
        let _ = writeln!(
            out,
            "faults: {} failed  {} cancelled  {} deadline_missed  {} shed",
            self.failed, self.cancelled, self.deadline_missed, self.shed
        );
        for (class, s) in &self.latency_by_class {
            let _ = writeln!(
                out,
                "  class {class:9} n={:<6} p50 {}  p99 {}",
                s.n,
                crate::util::fmt_us(s.p50),
                crate::util::fmt_us(s.p99),
            );
        }
        if let Some(snap) = self.snapshots.last() {
            let _ = writeln!(out, "{}", snap.render_line());
        }
        out
    }
}

struct ZooEntry {
    tag: String,
    graph: Graph,
    levels: Arc<[f64]>,
    peak_bytes: u64,
    weight: f64,
}

/// Everything the Chrome-trace exporter needs about one finished session.
/// Failed/cancelled sessions appear with empty records (the fleet drops
/// their partial trace) but keep their lifecycle instants.
struct CollectedSession {
    zoo: usize,
    seq: u64,
    submit_us: f64,
    end_us: f64,
    outcome: String,
    records: Vec<OpRecord>,
}

/// Run one closed-loop serve experiment; see the module docs.
pub fn serve(cfg: &ServeConfig) -> ServeReport {
    assert!(cfg.executors >= 1 && cfg.clients >= 1 && cfg.requests >= 1);
    assert!(!cfg.mix.is_empty(), "empty model mix");
    let total_weight: f64 = cfg.mix.iter().map(|(_, w)| w).sum();
    assert!(total_weight > 0.0, "mix weights must sum to something positive");

    // Pre-build the zoo once: graph, CP levels from the analytic cost
    // model, and the §5.1 planned peak footprint that admission charges.
    let cost = crate::cost::CostModel::knl();
    let zoo: Vec<ZooEntry> = cfg
        .mix
        .iter()
        .map(|&(kind, weight)| {
            let graph = if cfg.training {
                models::build(kind, cfg.size)
            } else {
                models::build_inference(kind, cfg.size)
            };
            let durations: Vec<f64> =
                graph.nodes().iter().map(|n| cost.duration_us(&n.kind, 8)).collect();
            let levels: Arc<[f64]> = cp_levels(&graph, &durations).into();
            let peak_bytes = plan_memory(&graph, &graph.topo_order()).arena_bytes;
            ZooEntry {
                tag: format!(
                    "{}-{}{}",
                    kind.name(),
                    cfg.size.name(),
                    if cfg.training { "" } else { "-inf" }
                ),
                graph,
                levels,
                peak_bytes,
                weight,
            }
        })
        .collect();

    const CLASSES: [&str; 4] = ["ok", "failed", "cancelled", "deadline"];
    let queue = SessionQueue::new(cfg.budget_bytes);
    let next_request = AtomicUsize::new(0);
    let completed_per_model: Vec<AtomicU64> = zoo.iter().map(|_| AtomicU64::new(0)).collect();
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(cfg.requests));
    let by_class: [Mutex<Vec<f64>>; 4] =
        [Mutex::new(Vec::new()), Mutex::new(Vec::new()), Mutex::new(Vec::new()), Mutex::new(Vec::new())];
    let session_dispatches = AtomicU64::new(0);
    let session_steals = AtomicU64::new(0);
    let in_flight = AtomicUsize::new(0);
    let max_in_flight = AtomicUsize::new(0);
    let admission_blocked = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let ring = TelemetryRing::new(cfg.telemetry_ring);
    let snapshots: Mutex<Vec<TelemetrySnapshot>> = Mutex::new(Vec::new());
    let collect_trace = cfg.trace_path.is_some();
    let collected: Mutex<Vec<CollectedSession>> = Mutex::new(Vec::new());
    // clients still running; the telemetry monitor exits when this hits 0
    let active_clients = AtomicUsize::new(cfg.clients);
    // ring sample class per by_class index (the report's CLASSES order)
    const CLASS_OUTCOMES: [OutcomeClass; 4] =
        [OutcomeClass::Ok, OutcomeClass::Failed, OutcomeClass::Cancelled, OutcomeClass::Deadline];
    let deadline = cfg.deadline_us.map(Duration::from_micros);
    // delay faults sleep long enough to trip a tight deadline (2×, capped
    // at 50ms so generous deadlines don't stall the run); without a
    // deadline they just stretch the session's tail latency
    let fault_delay_us = cfg.deadline_us.map(|d| (d as f64 * 2.0).min(50_000.0)).unwrap_or(200.0);
    let spin_us = cfg.op_spin_us;
    let work = move |_n: NodeId| {
        if spin_us > 0.0 {
            let t0 = Instant::now();
            while t0.elapsed().as_secs_f64() * 1e6 < spin_us {
                std::hint::spin_loop();
            }
        }
    };
    let work_ref: &(dyn Fn(NodeId) + Send + Sync) = &work;

    let t_start = Instant::now();
    let (totals, fleet_events) = std::thread::scope(|scope| {
        let fleet = Fleet::new(
            scope,
            FleetConfig {
                dispatch: cfg.dispatch,
                max_sessions: cfg.max_sessions,
                record_events: collect_trace,
                ..FleetConfig::new(cfg.executors)
            },
        );
        let fleet_ref = &fleet;
        // clients live in a nested scope so they may borrow the fleet —
        // and are all joined before the fleet shuts down
        std::thread::scope(|clients| {
            for c in 0..cfg.clients {
                let mut rng = Rng::new(cfg.seed ^ ((c as u64 + 1) << 40));
                let zoo = &zoo;
                let queue = &queue;
                let next_request = &next_request;
                let completed_per_model = &completed_per_model;
                let latencies = &latencies;
                let session_dispatches = &session_dispatches;
                let session_steals = &session_steals;
                let in_flight = &in_flight;
                let max_in_flight = &max_in_flight;
                let admission_blocked = &admission_blocked;
                let shed = &shed;
                let by_class = &by_class;
                let ring = &ring;
                let collected = &collected;
                let active_clients = &active_clients;
                clients.spawn(move || loop {
                    let i = next_request.fetch_add(1, Ordering::Relaxed);
                    if i >= cfg.requests {
                        active_clients.fetch_sub(1, Ordering::SeqCst);
                        return;
                    }
                    // weighted model pick
                    let mut draw = rng.f64() * total_weight;
                    let mut pick = zoo.len() - 1;
                    for (zi, z) in zoo.iter().enumerate() {
                        if draw < z.weight {
                            pick = zi;
                            break;
                        }
                        draw -= z.weight;
                    }
                    let z = &zoo[pick];
                    let plan = if cfg.fault_rate > 0.0 {
                        FaultPlan::draw(&mut rng, z.graph.len(), cfg.fault_rate, fault_delay_us)
                    } else {
                        FaultPlan::default()
                    };
                    let t0 = Instant::now();
                    // §5.1 admission: wait until the planned peak fits — for
                    // at most the deadline patience when one is configured
                    let permit = match queue.try_admit(z.peak_bytes) {
                        Some(p) => p,
                        None => {
                            admission_blocked.fetch_add(1, Ordering::Relaxed);
                            match deadline {
                                Some(d) => match queue.admit_timeout(z.peak_bytes, d) {
                                    Some(p) => p,
                                    None => {
                                        shed.fetch_add(1, Ordering::Relaxed);
                                        ring.push(SessionSample {
                                            t_us: fleet_ref.now_us(),
                                            latency_us: t0.elapsed().as_secs_f64() * 1e6,
                                            class: OutcomeClass::Shed,
                                            model: pick as u8,
                                        });
                                        continue;
                                    }
                                },
                                None => queue.admit(z.peak_bytes),
                            }
                        }
                    };
                    let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
                    max_in_flight.fetch_max(now, Ordering::SeqCst);
                    let handle = if plan.is_faulty() {
                        // faulty sessions own a wrapped closure; healthy
                        // ones keep the borrowed zero-allocation path
                        fleet_ref.submit_owned(
                            &z.graph,
                            Arc::clone(&z.levels),
                            Arc::new(plan.clone().wrap(work)),
                            deadline,
                        )
                    } else if let Some(d) = deadline {
                        fleet_ref.submit_with_deadline(&z.graph, Arc::clone(&z.levels), work_ref, d)
                    } else {
                        fleet_ref.submit(&z.graph, Arc::clone(&z.levels), work_ref)
                    };
                    if let Some(after_us) = plan.cancel_after_us {
                        std::thread::sleep(Duration::from_micros(after_us as u64));
                        handle.cancel();
                    }
                    // wait() consumes the handle — grab the trace identity first
                    let seq = handle.seq();
                    let submit_us = handle.submitted_at_us();
                    let outcome = handle.wait();
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    drop(permit);
                    let lat = t0.elapsed().as_secs_f64() * 1e6;
                    latencies.lock().unwrap().push(lat);
                    let class = match &outcome {
                        Ok(_) => 0,
                        Err(SessionError::Cancelled) => 2,
                        Err(SessionError::DeadlineExceeded) => 3,
                        Err(_) => 1,
                    };
                    by_class[class].lock().unwrap().push(lat);
                    ring.push(SessionSample {
                        t_us: fleet_ref.now_us(),
                        latency_us: lat,
                        class: CLASS_OUTCOMES[class],
                        model: pick as u8,
                    });
                    if collect_trace {
                        let (cause, end_us, records) = match &outcome {
                            Ok(r) => ("done", submit_us + r.wall_us, r.records.clone()),
                            Err(SessionError::Cancelled) => {
                                ("cancelled", fleet_ref.now_us(), Vec::new())
                            }
                            Err(SessionError::DeadlineExceeded) => {
                                ("deadline", fleet_ref.now_us(), Vec::new())
                            }
                            Err(SessionError::Stalled) => ("stalled", fleet_ref.now_us(), Vec::new()),
                            Err(SessionError::OpPanicked { .. }) => {
                                ("failed", fleet_ref.now_us(), Vec::new())
                            }
                        };
                        collected.lock().unwrap().push(CollectedSession {
                            zoo: pick,
                            seq,
                            submit_us,
                            end_us,
                            outcome: cause.to_string(),
                            records,
                        });
                    }
                    if let Ok(report) = outcome {
                        completed_per_model[pick].fetch_add(1, Ordering::Relaxed);
                        session_dispatches.fetch_add(report.dispatches, Ordering::Relaxed);
                        session_steals.fetch_add(report.steals, Ordering::Relaxed);
                    }
                });
            }
            if let Some(every_ms) = cfg.telemetry_every_ms {
                let ring = &ring;
                let snapshots = &snapshots;
                let active_clients = &active_clients;
                let queue = &queue;
                let in_flight = &in_flight;
                clients.spawn(move || {
                    let mut prev: Option<TelemetrySnapshot> = None;
                    loop {
                        // sleep in short slices so the monitor notices the
                        // run ending instead of overshooting by an interval
                        let mut slept_ms = 0u64;
                        while slept_ms < every_ms && active_clients.load(Ordering::SeqCst) > 0 {
                            let slice = (every_ms - slept_ms).min(20);
                            std::thread::sleep(Duration::from_millis(slice));
                            slept_ms += slice;
                        }
                        if active_clients.load(Ordering::SeqCst) == 0 {
                            return;
                        }
                        let snap = ring.snapshot(
                            fleet_ref.now_us(),
                            fleet_ref.totals(),
                            queue.waiting(),
                            in_flight.load(Ordering::SeqCst),
                            prev.as_ref(),
                        );
                        println!("{}", snap.render_line());
                        snapshots.lock().unwrap().push(snap.clone());
                        prev = Some(snap);
                    }
                });
            }
        });
        // final snapshot: every run reports at least one, interval or not
        {
            let prev = snapshots.lock().unwrap().last().cloned();
            let snap =
                ring.snapshot(fleet.now_us(), fleet.totals(), queue.waiting(), 0, prev.as_ref());
            snapshots.lock().unwrap().push(snap);
        }
        let fleet_events = fleet.drain_events();
        // a faulty run reports its failures through the per-class counts;
        // the shutdown error carries the same totals snapshot
        let totals = match fleet.shutdown() {
            Ok(t) => t,
            Err(e) => e.totals,
        };
        (totals, fleet_events)
    });
    let wall_s = t_start.elapsed().as_secs_f64();

    if let Some(path) = &cfg.trace_path {
        let mut sessions = collected.into_inner().unwrap();
        sessions.sort_by_key(|s| s.seq);
        let exports: Vec<SessionTraceExport<'_>> = sessions
            .iter()
            .map(|c| SessionTraceExport {
                label: format!("session {} ({})", c.seq, zoo[c.zoo].tag),
                graph: &zoo[c.zoo].graph,
                levels: Some(&zoo[c.zoo].levels[..]),
                records: &c.records,
                start_us: c.submit_us,
                end_us: c.end_us,
                outcome: c.outcome.clone(),
            })
            .collect();
        let text = export_chrome_trace(&exports, &fleet_events, cfg.executors);
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        std::fs::write(path, text)
            .unwrap_or_else(|e| panic!("failed to write serve trace to {path}: {e}"));
    }

    let latencies = latencies.into_inner().unwrap();
    let class_samples: Vec<Vec<f64>> =
        by_class.into_iter().map(|m| m.into_inner().unwrap()).collect();
    let completed = class_samples[0].len();
    ServeReport {
        dispatch: cfg.dispatch,
        completed,
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        latency_us: if latencies.is_empty() {
            Summary::from_samples(&[0.0])
        } else {
            Summary::from_samples(&latencies)
        },
        per_model: zoo
            .iter()
            .zip(&completed_per_model)
            .map(|(z, n)| (z.tag.clone(), n.load(Ordering::SeqCst), z.peak_bytes))
            .collect(),
        totals,
        session_dispatches: session_dispatches.load(Ordering::SeqCst),
        session_steals: session_steals.load(Ordering::SeqCst),
        max_in_flight: max_in_flight.load(Ordering::SeqCst),
        admission_blocked: admission_blocked.load(Ordering::SeqCst),
        failed: totals.sessions_failed,
        cancelled: totals.sessions_cancelled,
        deadline_missed: totals.sessions_deadline_missed,
        shed: shed.load(Ordering::SeqCst),
        latency_by_class: CLASSES
            .iter()
            .zip(&class_samples)
            .filter_map(|(c, s)| Summary::from_samples_opt(s).map(|sum| (c.to_string(), sum)))
            .collect(),
        snapshots: snapshots.into_inner().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(mode: DispatchMode) -> ServeConfig {
        ServeConfig {
            executors: 2,
            dispatch: mode,
            clients: 2,
            requests: 12,
            mix: vec![(ModelKind::Mlp, 1.0)],
            ..ServeConfig::default()
        }
    }

    #[test]
    fn closed_loop_completes_every_request_in_both_modes() {
        for mode in DispatchMode::ALL {
            let report = serve(&quick(mode));
            assert_eq!(report.completed, 12, "{}", mode.name());
            assert_eq!(report.totals.sessions_completed, 12, "{}", mode.name());
            assert_eq!(report.latency_us.n, 12, "{}", mode.name());
            assert!(report.throughput_rps > 0.0, "{}", mode.name());
            // per-session metric partition: sums match the fleet totals
            assert_eq!(report.session_dispatches, report.totals.dispatches, "{}", mode.name());
            assert!(report.session_steals <= report.totals.steals, "{}", mode.name());
            let per_model_total: u64 = report.per_model.iter().map(|(_, n, _)| n).sum();
            assert_eq!(per_model_total, 12, "{}", mode.name());
            let text = report.render();
            assert!(text.contains("sessions/s"), "{text}");
        }
    }

    #[test]
    fn tight_budget_serializes_but_still_completes() {
        // a budget of one byte forces every session to run alone: the
        // closed loop must degrade to serial admission, not deadlock
        let cfg = ServeConfig { budget_bytes: 1, ..quick(DispatchMode::Decentralized) };
        let report = serve(&cfg);
        assert_eq!(report.completed, 12);
        assert_eq!(report.max_in_flight, 1, "one-byte budget ⇒ strictly serial sessions");
        // (whether a client ever *observed* the full budget is a scheduling
        // race; the deterministic blocking proof lives in the SessionQueue
        // unit tests and tests/serve_sessions.rs)
    }

    #[test]
    fn seeded_faults_are_reported_and_conserved() {
        for mode in DispatchMode::ALL {
            let cfg = ServeConfig {
                executors: 2,
                dispatch: mode,
                clients: 2,
                requests: 40,
                mix: vec![(ModelKind::Mlp, 1.0)],
                fault_rate: 1.0,
                ..ServeConfig::default()
            };
            let report = serve(&cfg);
            // every request is accounted for exactly once
            assert_eq!(
                report.completed as u64
                    + report.failed
                    + report.cancelled
                    + report.deadline_missed
                    + report.shed,
                40,
                "{}: {report:?}",
                mode.name()
            );
            // rate 1.0 over 40 draws: a panic plan is (overwhelmingly,
            // and for seed 42 deterministically) among them, and every
            // panic plan fails its session
            assert!(report.failed > 0, "{}", mode.name());
            // the fleet survived every fault: completions the counters
            // agree on, plus a latency sample for every non-shed request
            assert_eq!(report.totals.sessions_completed, report.completed as u64, "{}", mode.name());
            let class_n: u64 = report.latency_by_class.iter().map(|(_, s)| s.n as u64).sum();
            assert_eq!(class_n + report.shed, 40, "{}", mode.name());
            let text = report.render();
            assert!(text.contains("failed"), "{text}");
        }
    }

    #[test]
    fn tight_deadline_misses_are_counted() {
        let cfg = ServeConfig {
            executors: 2,
            clients: 2,
            requests: 8,
            mix: vec![(ModelKind::Mlp, 1.0)],
            op_spin_us: 50.0,
            deadline_us: Some(1),
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        // a 1µs deadline over 50µs ops: no mlp session can finish in time,
        // and a request that cannot even get admitted in time is shed
        assert_eq!(report.deadline_missed + report.shed, 8, "{report:?}");
        assert_eq!(report.completed, 0, "{report:?}");
    }

    #[test]
    fn mixed_zoo_spreads_requests_across_models() {
        let cfg = ServeConfig {
            executors: 2,
            clients: 3,
            requests: 24,
            mix: vec![(ModelKind::Mlp, 1.0), (ModelKind::PathNet, 1.0)],
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 24);
        // with an even weighting over 24 requests, both models must appear
        let counts: Vec<u64> = report.per_model.iter().map(|(_, n, _)| *n).collect();
        assert_eq!(counts.iter().sum::<u64>(), 24);
        assert!(counts.iter().all(|&n| n > 0), "both mix entries must be exercised: {counts:?}");
    }

    #[test]
    fn degenerate_runs_keep_latency_summaries_finite() {
        // a single request: one sample per summary, every percentile finite
        let cfg = ServeConfig {
            executors: 2,
            clients: 1,
            requests: 1,
            mix: vec![(ModelKind::Mlp, 1.0)],
            telemetry_ring: 4,
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 1);
        assert!(report.latency_us.p50.is_finite() && report.latency_us.p99.is_finite());
        assert_eq!(report.latency_by_class.len(), 1, "only the ok class has samples");
        for (class, s) in &report.latency_by_class {
            assert_eq!(s.n, 1, "{class}");
            assert!(s.p50.is_finite() && s.p99.is_finite(), "{class}");
            assert_eq!(s.p50, s.p99, "single sample: every percentile is it");
        }
        // the final telemetry snapshot is always present and finite
        let snap = report.snapshots.last().expect("final snapshot");
        assert_eq!(snap.total_sessions, 1);
        assert!(snap.rps.is_finite() && snap.steal_rate.is_finite());
        for (class, s) in &snap.per_class {
            assert!(s.p50.is_finite() && s.p99.is_finite(), "{}", class.name());
        }
        let text = report.render();
        assert!(text.contains("telemetry"), "{text}");
    }

    #[test]
    fn trace_export_covers_every_session_and_validates() {
        let path = std::env::temp_dir()
            .join(format!("graphi-serve-trace-{}.json", std::process::id()));
        let cfg = ServeConfig {
            executors: 2,
            clients: 2,
            requests: 8,
            mix: vec![(ModelKind::Mlp, 1.0)],
            trace_path: Some(path.to_string_lossy().into_owned()),
            ..ServeConfig::default()
        };
        let report = serve(&cfg);
        assert_eq!(report.completed, 8);
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let stats = crate::engine::validate_chrome_trace(&text).unwrap();
        assert_eq!(stats.processes, 1 + 8, "the fleet plus one process per session");
        assert!(stats.spans > 0);
        assert!(stats.instant_names.contains("admitted"), "{:?}", stats.instant_names);
        assert!(stats.instant_names.contains("done"), "{:?}", stats.instant_names);
    }
}
