//! The Graphi engine on *real* host threads.
//!
//! Same architecture as §4/§5 — a centralized scheduler thread (here: the
//! calling thread), a fleet of executor threads, per-executor SPSC
//! operation buffers, and a **single bounded MPSC completion queue**
//! flowing completions back (executors produce, the scheduler consumes) —
//! with actual parallel execution of an arbitrary work function (the
//! end-to-end example plugs PJRT executions in; tests use synthetic
//! spin-work).
//!
//! The completion queue replaces the seed design's per-executor "done
//! rings": those forced the scheduler to scan every executor's ring on
//! every loop iteration (O(executors) shared-cache-line loads even when
//! idle). With one [`MpscQueue`], an idle poll is a single acquire load,
//! completions drain in arrival order in batches, and dispatch fills each
//! executor's operation buffer through the SPSC ring's batched push.
//!
//! On this repo's 1-core CI machine the fleet cannot show parallel
//! *speedup*; what it demonstrates is that the scheduler core (bitmap +
//! heap + rings) is real concurrent code producing valid schedules, and it
//! is the engine the paper's system would ship on real silicon.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use crate::engine::mpsc::MpscQueue;
use crate::engine::policies::Policy;
use crate::engine::ready::{DepTracker, ReadySet};
use crate::engine::ring::SpscRing;
use crate::engine::scheduler::IdleBitmap;
use crate::engine::trace::OpRecord;
use crate::graph::{Graph, NodeId};

/// Real-threads Graphi configuration.
#[derive(Debug, Clone)]
pub struct ThreadedGraphi {
    /// Executor threads to spawn.
    pub executors: usize,
    /// Ready-op ordering.
    pub policy: Policy,
    /// Per-executor operation buffer depth (§5.2 uses 1).
    pub buffer_depth: usize,
}

impl ThreadedGraphi {
    pub fn new(executors: usize) -> ThreadedGraphi {
        ThreadedGraphi { executors, policy: Policy::CriticalPathFirst, buffer_depth: 1 }
    }

    /// Fleet shape from a persisted tuning artifact (the autotuner's
    /// winning executor count).
    pub fn from_tuning(tuning: &crate::runtime::artifacts::TuningArtifact) -> ThreadedGraphi {
        ThreadedGraphi::new(tuning.best.0.max(1))
    }
}

/// Result of a threaded run.
#[derive(Debug)]
pub struct ThreadedRunResult {
    /// Wall-clock makespan, µs.
    pub wall_us: f64,
    /// Per-op records (wall-clock µs since run start).
    pub records: Vec<OpRecord>,
    /// Scheduler dispatch count.
    pub dispatches: u64,
}

impl ThreadedGraphi {
    /// Execute `graph`, calling `work(node)` for each op on some executor
    /// thread, dependencies respected. `levels` orders ready ops (pass
    /// profiled level values, or unit levels).
    pub fn run<F>(&self, graph: &Graph, levels: &[f64], work: F) -> ThreadedRunResult
    where
        F: Fn(NodeId) + Send + Sync,
    {
        assert_eq!(levels.len(), graph.len());
        assert!(self.executors >= 1);
        let n_exec = self.executors;
        let op_rings: Vec<SpscRing<NodeId>> =
            (0..n_exec).map(|_| SpscRing::new(self.buffer_depth)).collect();
        // one completion queue shared by all executors; sized for the whole
        // graph so a push can never fail (each node completes exactly once)
        let done_q: MpscQueue<(u32, NodeId)> = MpscQueue::new(graph.len() + 1);
        let shutdown = AtomicBool::new(false);
        let t0 = Instant::now();

        let mut all_records: Vec<Vec<OpRecord>> = Vec::new();
        let mut dispatches = 0u64;

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n_exec);
            for e in 0..n_exec {
                let op_ring = &op_rings[e];
                let done_q = &done_q;
                let shutdown = &shutdown;
                let work = &work;
                handles.push(scope.spawn(move || {
                    // Algorithm 2: poll own buffer, execute, report back.
                    let mut records = Vec::new();
                    loop {
                        if let Some(node) = op_ring.pop() {
                            let start = t0.elapsed().as_secs_f64() * 1e6;
                            work(node);
                            let end = t0.elapsed().as_secs_f64() * 1e6;
                            records.push(OpRecord {
                                node,
                                executor: e as u32,
                                start_us: start,
                                end_us: end,
                            });
                            // report completion to the shared queue (§4.4)
                            done_q
                                .push((e as u32, node))
                                .expect("completion queue sized for whole graph");
                        } else if shutdown.load(Ordering::Acquire) {
                            return records;
                        } else {
                            std::hint::spin_loop();
                            std::thread::yield_now();
                        }
                    }
                }));
            }

            // ---- scheduler (Algorithm 1) on the calling thread ----
            // Executor availability is tracked as a bitmap (§5.2); a bit is
            // set when the executor's depth-bounded operation buffer has
            // room. With depth 1 this is the paper's "buffer at most one
            // operation" behaviour: the scheduler can stage the next op
            // while the current one runs, and no deeper (avoiding the load
            // imbalance §5.2 observed with larger buffers).
            let mut deps = DepTracker::new(graph);
            let mut ready = ReadySet::new(self.policy, levels, 0);
            let mut available = IdleBitmap::new(n_exec);
            let mut inflight = vec![0usize; n_exec];
            let mut completions: Vec<(u32, NodeId)> = Vec::with_capacity(n_exec * 2 + 8);
            for s in deps.sources() {
                ready.push(s);
            }
            while !deps.is_done() {
                // drain the shared completion queue in one batch — a single
                // acquire load when idle, no per-executor scan
                completions.clear();
                done_q.pop_batch(&mut completions, usize::MAX);
                for &(e, node) in completions.iter() {
                    let e = e as usize;
                    inflight[e] -= 1;
                    if inflight[e] == self.buffer_depth - 1 && !available.is_idle(e) {
                        available.set_idle(e);
                    }
                    deps.complete(graph, node, |n| ready.push(n));
                }
                // dispatch: max-level ops → first available executor
                // (bit-scan), filling its buffer through one batched push
                let mut progressed = false;
                while !ready.is_empty() && available.any_idle() {
                    let e = available.first_idle().unwrap();
                    let room = self.buffer_depth - inflight[e];
                    let mut feed = std::iter::from_fn(|| ready.pop()).take(room);
                    let pushed = op_rings[e].push_batch(&mut feed);
                    debug_assert!(pushed > 0, "availability bit ⇒ ring space");
                    dispatches += pushed as u64;
                    progressed = true;
                    inflight[e] += pushed;
                    if inflight[e] >= self.buffer_depth {
                        available.set_busy(e);
                    }
                }
                // On the paper's machine the scheduler owns a reserved core
                // and busy-polls (§5.2). On an oversubscribed host (e.g. a
                // 1-core CI box) pure spinning starves the executor threads
                // of their timeslice — yield whenever no dispatch happened
                // so completions can actually arrive (§Perf L3 iteration 1:
                // 2.9 s → ~ms-scale for a ~1.5k-op graph).
                if !progressed {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            }
            shutdown.store(true, Ordering::Release);
            for h in handles {
                all_records.push(h.join().expect("executor thread panicked"));
            }
        });

        let mut records: Vec<OpRecord> = all_records.into_iter().flatten().collect();
        records.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        let wall_us = t0.elapsed().as_secs_f64() * 1e6;
        ThreadedRunResult { wall_us, records, dispatches }
    }

    /// Execute `graph` with critical-path levels derived from a tuning
    /// artifact's profiled per-op duration table (§4.2 fed back into the
    /// real-threads engine), instead of caller-supplied levels.
    pub fn run_tuned<F>(
        &self,
        graph: &Graph,
        tuning: &crate::runtime::artifacts::TuningArtifact,
        work: F,
    ) -> ThreadedRunResult
    where
        F: Fn(NodeId) + Send + Sync,
    {
        assert!(
            tuning.matches_graph(graph.len()),
            "tuning artifact for {} nodes applied to a {}-node graph",
            tuning.graph_nodes,
            graph.len()
        );
        let levels = crate::graph::levels(graph, &tuning.durations_us);
        self.run(graph, &levels, work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build as mlp, MlpConfig};
    use crate::models::{self, ModelKind, ModelSize};
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_every_op_exactly_once() {
        let g = mlp(&MlpConfig::default());
        let counter = AtomicU64::new(0);
        let engine = ThreadedGraphi::new(3);
        let result = engine.run(&g, &vec![1.0; g.len()], |_n| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64);
        assert_eq!(result.records.len(), g.len());
        assert_eq!(result.dispatches, g.len() as u64);
    }

    #[test]
    fn respects_dependencies_under_real_concurrency() {
        // Record completion order with an atomic clock and verify
        // topological consistency — on real threads, with 4 executors.
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let clock = AtomicU64::new(0);
        let stamp: Vec<AtomicU64> = (0..g.len()).map(|_| AtomicU64::new(u64::MAX)).collect();
        let engine = ThreadedGraphi::new(4);
        engine.run(&g, &vec![1.0; g.len()], |n| {
            // simulate a little work to widen race windows
            for _ in 0..100 {
                std::hint::spin_loop();
            }
            let t = clock.fetch_add(1, Ordering::SeqCst);
            stamp[n as usize].store(t, Ordering::SeqCst);
        });
        for v in 0..g.len() as NodeId {
            for &p in g.preds(v) {
                let tp = stamp[p as usize].load(Ordering::SeqCst);
                let tv = stamp[v as usize].load(Ordering::SeqCst);
                assert!(tp < tv, "dep violated: {p} (t={tp}) vs {v} (t={tv})");
            }
        }
    }

    #[test]
    fn run_tuned_uses_artifact_fleet_and_durations() {
        use crate::runtime::artifacts::{TuningArtifact, TUNING_FORMAT_VERSION};
        let g = mlp(&MlpConfig::default());
        let tuning = TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: "mlp-test".to_string(),
            worker_cores: 64,
            seed: 0,
            graph_nodes: g.len(),
            best: (3, 21),
            best_makespan_us: 1.0,
            total_profile_iterations: 1,
            durations_us: vec![2.0; g.len()],
            search_trace: Vec::new(),
        };
        let engine = ThreadedGraphi::from_tuning(&tuning);
        assert_eq!(engine.executors, 3);
        let counter = AtomicU64::new(0);
        let result = engine.run_tuned(&g, &tuning, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64);
        assert_eq!(result.records.len(), g.len());
    }

    #[test]
    fn single_executor_works() {
        let g = mlp(&MlpConfig::default());
        let engine = ThreadedGraphi::new(1);
        let result = engine.run(&g, &vec![1.0; g.len()], |_| {});
        assert_eq!(result.records.len(), g.len());
    }

    #[test]
    fn cp_first_orders_by_level_on_single_executor() {
        // with 1 executor and depth-1 buffering, dispatch order follows
        // level priority among simultaneously-ready ops
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let _a = b.add("a", OpKind::Scalar);
        let _bb = b.add("b", OpKind::Scalar);
        let _c = b.add("c", OpKind::Scalar);
        let g = b.build().unwrap();
        // levels make node 2 hottest, then 0, then 1
        let levels = vec![5.0, 1.0, 9.0];
        let order = std::sync::Mutex::new(Vec::new());
        ThreadedGraphi::new(1).run(&g, &levels, |n| {
            order.lock().unwrap().push(n);
        });
        let order = order.into_inner().unwrap();
        assert_eq!(order, vec![2, 0, 1]);
    }
}
