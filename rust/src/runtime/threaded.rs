//! The Graphi engine on *real* host threads, in two dispatch architectures.
//!
//! Since PR 5 both architectures run on the **session core**
//! ([`crate::runtime::fleet`]): a persistent [`Fleet`] of executor threads
//! and per-graph [`Session`](crate::runtime::fleet::SessionHandle)s.
//! `ThreadedGraphi::run` is submit-one-session-and-wait — it builds a
//! fleet scoped to the call, submits the graph as the fleet's only
//! session, waits for its quiescence, and shuts the fleet down — so every
//! test and bench of this type exercises the same engine `graphi serve`
//! keeps hot across thousands of sessions.
//!
//! **Centralized** (§4/§5, PR 1): a dedicated scheduler thread, a fleet of
//! executor threads, per-executor SPSC operation buffers, and a single
//! bounded MPSC completion queue flowing completions back. Every
//! completion round-trips executor → queue → dep tracker → ready-heap →
//! SPSC ring → executor, serializing dispatch on one thread.
//!
//! **Decentralized** (PR 3, the default): the common case never touches a
//! coordinator. Executors share the graph's CSR successor layout through an
//! [`AtomicDepTracker`](crate::graph::AtomicDepTracker); the executor
//! finishing op `n` `fetch_sub`s each successor's remaining-deps counter
//! and pushes newly-ready ops onto its own work-stealing deque (packed
//! CP-level keys). Local pops take the LIFO end for cache affinity; idle
//! executors steal the highest-priority exposed entry, preserving §4.3
//! CP-first semantics (see [`crate::engine::worksteal`] for the full
//! argument).
//!
//! Three topology/phase refinements (PR 4) sit on top:
//!
//! * **NUMA-aware victim selection**: give the engine a
//!   [`DomainMap`] (e.g. via [`ThreadedGraphi::with_numa`]) and idle
//!   executors prefer same-domain victims, crossing the boundary only for
//!   a strictly deeper critical path — §2's SNC modes make remote-slice
//!   traffic expensive, and the simulator prices the crossing with
//!   `Calibration::steal_cross_domain_us`.
//! * **Adaptive idle backoff**: the idle loop is a spin→yield→park state
//!   machine ([`crate::engine::backoff`]); producers bump an
//!   [`EventCounter`](crate::engine::backoff::EventCounter) after every
//!   push, so parked executors wake without polling and idle executors
//!   stop burning the cores busy executors' op teams need (the §3
//!   contention argument).
//! * **Per-phase dispatch**: a [`PhasePlan`] runs each width phase of the
//!   graph under its own mode with a barrier at phase boundaries
//!   ([`ThreadedGraphi::run`] dispatches to `run_phased`); tuning
//!   artifacts (format v3) carry the plan the autotuner found.
//!
//! On this repo's 1-core CI machine the fleet cannot show parallel
//! *speedup*; what it demonstrates is that both dispatch paths are real
//! concurrent code producing valid schedules, and the decentralized path
//! is the engine the paper's system would want once op rates outrun a
//! single scheduler core.

use std::fmt;
use std::sync::Arc;

use crate::engine::policies::Policy;
use crate::engine::trace::{FleetEvent, FleetEventKind, OpRecord, FLEET_LANE};
use crate::engine::worksteal::DomainMap;
use crate::engine::{DispatchMode, PhasePlan};
use crate::graph::{phase_members, width_phases, Graph, NodeId};
use crate::runtime::fleet::{Fleet, FleetConfig};

/// Real-threads Graphi configuration.
#[derive(Debug, Clone)]
pub struct ThreadedGraphi {
    /// Executor threads to spawn.
    pub executors: usize,
    /// Ready-op ordering. The session core is CP-first by construction
    /// (packed level keys); `AntiCritical` is honored by negating the
    /// levels, the other ready-set policies exist only on the simulated
    /// engines.
    pub policy: Policy,
    /// Per-executor operation buffer depth (§5.2 uses 1; centralized mode).
    pub buffer_depth: usize,
    /// Completion-resolution architecture.
    pub dispatch: DispatchMode,
    /// Executor→NUMA-domain map for victim ranking in decentralized mode.
    /// `None` = flat (domain-blind ranking, the quadrant-mode behaviour).
    pub numa: Option<DomainMap>,
    /// Per-phase dispatch assignment; overrides `dispatch` when set.
    pub phase_plan: Option<PhasePlan>,
    /// Record steal/park/mode-switch events into
    /// [`ThreadedRunResult::events`] for the Chrome-trace exporter. Off by
    /// default (zero hot-path cost when off).
    pub record_events: bool,
}

impl ThreadedGraphi {
    pub fn new(executors: usize) -> ThreadedGraphi {
        ThreadedGraphi {
            executors,
            policy: Policy::CriticalPathFirst,
            buffer_depth: 1,
            dispatch: DispatchMode::Decentralized,
            numa: None,
            phase_plan: None,
            record_events: false,
        }
    }

    pub fn with_dispatch(mut self, dispatch: DispatchMode) -> ThreadedGraphi {
        self.dispatch = dispatch;
        self
    }

    /// Topology-aware victim selection from an explicit executor→domain
    /// map (see [`DomainMap`]).
    pub fn with_numa(mut self, map: DomainMap) -> ThreadedGraphi {
        assert_eq!(map.len(), self.executors, "one domain per executor");
        self.numa = Some(map);
        self
    }

    /// Derive the domain map from a machine description's fleet striping
    /// ([`crate::cost::machine::Machine::executor_domain_map`]).
    pub fn with_numa_machine(
        self,
        machine: &crate::cost::machine::Machine,
        threads_per: usize,
    ) -> ThreadedGraphi {
        let map = DomainMap::of_fleet(machine, self.executors, threads_per);
        self.with_numa(map)
    }

    /// Run each width phase under its own dispatch mode.
    pub fn with_phase_plan(mut self, plan: PhasePlan) -> ThreadedGraphi {
        self.phase_plan = Some(plan);
        self
    }

    /// Record steal/park/mode-switch events for trace export.
    pub fn with_event_recording(mut self, on: bool) -> ThreadedGraphi {
        self.record_events = on;
        self
    }

    /// Fleet shape, dispatch mode and phase plan from a persisted tuning
    /// artifact.
    pub fn from_tuning(tuning: &crate::runtime::artifacts::TuningArtifact) -> ThreadedGraphi {
        ThreadedGraphi {
            dispatch: tuning.best_dispatch,
            phase_plan: tuning.phase_plan.clone(),
            ..ThreadedGraphi::new(tuning.best.0.max(1))
        }
    }
}

/// A ready-set policy the threaded session core cannot honor.
///
/// The session core is CP-first by construction (packed level keys):
/// `AntiCritical` is expressible by negating the levels, but
/// `Fifo`/`Lifo`/`Random` only ever ordered the PR-1 centralized heap and
/// have no session-core equivalent. [`ThreadedGraphi::run`] refuses them
/// with this structured error — surfaced through the CLI's error chain —
/// rather than silently scheduling under a different policy than
/// requested (or, as before, panicking).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnsupportedPolicy {
    /// The refused policy.
    pub policy: Policy,
}

impl fmt::Display for UnsupportedPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "policy {:?} is not supported by the threaded session core (CP-first by \
             construction); use the simulated engines for alternative ready-set policies",
            self.policy
        )
    }
}

impl std::error::Error for UnsupportedPolicy {}

/// Result of a threaded run.
#[derive(Debug)]
pub struct ThreadedRunResult {
    /// Wall-clock makespan, µs.
    pub wall_us: f64,
    /// Per-op records (wall-clock µs since run start).
    pub records: Vec<OpRecord>,
    /// Dispatch decisions (centralized: scheduler pushes; decentralized:
    /// local pops + steals).
    pub dispatches: u64,
    /// Decentralized mode: ops acquired by stealing (0 when centralized).
    pub steals: u64,
    /// Of `steals`, how many crossed a NUMA-domain boundary (0 without a
    /// multi-domain [`DomainMap`]).
    pub cross_domain_steals: u64,
    /// Times an idle fleet thread (executor, or the centralized
    /// scheduler thread) reached the park stage of the backoff state
    /// machine and actually slept on the event counter.
    pub parks: u64,
    /// Phased runs: phase boundaries where the dispatch mode changed.
    pub mode_switches: u64,
    /// Steal/park/mode-switch events on the run's own clock (µs since
    /// submit, like `records`). Empty unless
    /// [`ThreadedGraphi::with_event_recording`] was set.
    pub events: Vec<FleetEvent>,
}

impl ThreadedGraphi {
    /// Execute `graph`, calling `work(node)` for each op on some executor
    /// thread, dependencies respected. `levels` orders ready ops (pass
    /// profiled level values, or unit levels); `Vec` callers move, `Arc`
    /// callers share — no per-run O(nodes) copy either way.
    ///
    /// Implemented as submit-one-session-and-wait on the session core
    /// ([`crate::runtime::fleet`]): a fleet scoped to this call executes
    /// the graph as its only session, so the engine under test here is the
    /// same one `graphi serve` keeps persistent across many sessions.
    ///
    /// `Err` only for a policy the session core cannot honor
    /// ([`UnsupportedPolicy`]). A `work` closure that panics propagates
    /// the panic to this caller (the session core catches it, confines it
    /// to the session, and this single-session wrapper re-raises it —
    /// run-one-graph semantics are unchanged from the pre-fleet era).
    pub fn run<F>(
        &self,
        graph: &Graph,
        levels: impl Into<Arc<[f64]>>,
        work: F,
    ) -> Result<ThreadedRunResult, UnsupportedPolicy>
    where
        F: Fn(NodeId) + Send + Sync,
    {
        let levels: Arc<[f64]> = levels.into();
        assert_eq!(levels.len(), graph.len());
        assert!(self.executors >= 1);
        if let Some(plan) = &self.phase_plan {
            return self.run_phased(graph, &levels, plan, &work);
        }
        // the session core is CP-first by construction (packed level
        // keys): AntiCritical is expressible by negating the levels; the
        // remaining policies only ever ordered the PR-1 centralized heap
        // and have no session-core equivalent — refuse with a structured
        // error rather than silently scheduling under a different policy
        let levels: Arc<[f64]> = match self.policy {
            Policy::CriticalPathFirst => levels,
            Policy::AntiCritical => levels.iter().map(|&l| -l).collect::<Vec<f64>>().into(),
            other => return Err(UnsupportedPolicy { policy: other }),
        };
        let config = FleetConfig {
            executors: self.executors,
            dispatch: self.dispatch,
            buffer_depth: self.buffer_depth,
            numa: self.numa.clone(),
            max_sessions: 1,
            deque_capacity: graph.len().max(64),
            watchdog: None,
            record_events: self.record_events,
        };
        Ok(std::thread::scope(|scope| {
            let fleet = Fleet::new(scope, config);
            let session = fleet.submit(graph, levels, &work);
            let report = session
                .wait()
                .unwrap_or_else(|e| panic!("threaded single-session run failed: {e}"));
            // re-base fleet events onto the session clock so they share a
            // timeline with the (submit-relative) records
            let mut events = fleet.drain_events();
            for ev in &mut events {
                ev.t_us -= report.submitted_at_us;
            }
            let totals = fleet.shutdown().expect("no faults after a clean session");
            ThreadedRunResult {
                wall_us: report.wall_us,
                records: report.records,
                dispatches: report.dispatches,
                steals: report.steals,
                cross_domain_steals: report.cross_domain_steals,
                parks: totals.parks,
                mode_switches: 0,
                events,
            }
        }))
    }

    /// Execute a [`PhasePlan`]: each width phase runs as an induced
    /// subgraph under its own dispatch mode, with a barrier (thread-fleet
    /// quiescence + re-seed) at every phase boundary. Dependency-safe by
    /// construction — a node's predecessors are never in a later phase.
    fn run_phased<F>(
        &self,
        graph: &Graph,
        levels: &Arc<[f64]>,
        plan: &PhasePlan,
        work: &F,
    ) -> Result<ThreadedRunResult, UnsupportedPolicy>
    where
        F: Fn(NodeId) + Send + Sync,
    {
        let phases = width_phases(graph, plan.threshold);
        assert_eq!(
            plan.modes.len(),
            phases.len(),
            "phase plan ({} modes) does not line up with the graph ({} phases at threshold {})",
            plan.modes.len(),
            phases.len(),
            plan.threshold
        );
        let members = phase_members(graph, &phases);
        let uniform = ThreadedGraphi { phase_plan: None, ..self.clone() };
        let mut records: Vec<OpRecord> = Vec::with_capacity(graph.len());
        let mut offset_us = 0.0f64;
        let mut dispatches = 0u64;
        let mut steals = 0u64;
        let mut cross_domain_steals = 0u64;
        let mut parks = 0u64;
        let mut mode_switches = 0u64;
        let mut events: Vec<FleetEvent> = Vec::new();
        let mut prev_mode: Option<DispatchMode> = None;
        for (mode, keep) in plan.modes.iter().zip(&members) {
            if let Some(p) = prev_mode {
                if p != *mode {
                    mode_switches += 1;
                    if self.record_events {
                        events.push(FleetEvent {
                            t_us: offset_us,
                            executor: FLEET_LANE,
                            kind: FleetEventKind::ModeSwitch { from: p, to: *mode },
                        });
                    }
                }
            }
            prev_mode = Some(*mode);
            let (sub, map) = graph.induced_subgraph(keep);
            let sub_levels: Vec<f64> = map.iter().map(|&v| levels[v as usize]).collect();
            let engine = ThreadedGraphi { dispatch: *mode, ..uniform.clone() };
            let map_ref = &map;
            let r = engine.run(&sub, sub_levels, move |n: NodeId| work(map_ref[n as usize]))?;
            for rec in r.records {
                records.push(OpRecord {
                    node: map[rec.node as usize],
                    executor: rec.executor,
                    start_us: rec.start_us + offset_us,
                    end_us: rec.end_us + offset_us,
                });
            }
            for mut ev in r.events {
                ev.t_us += offset_us;
                events.push(ev);
            }
            offset_us += r.wall_us;
            dispatches += r.dispatches;
            steals += r.steals;
            cross_domain_steals += r.cross_domain_steals;
            parks += r.parks;
        }
        records.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        events.sort_by(|a, b| a.t_us.total_cmp(&b.t_us));
        Ok(ThreadedRunResult {
            wall_us: offset_us,
            records,
            dispatches,
            steals,
            cross_domain_steals,
            parks,
            mode_switches,
            events,
        })
    }

    /// Execute `graph` with critical-path levels derived from a tuning
    /// artifact's profiled per-op duration table (§4.2 fed back into the
    /// real-threads engine), instead of caller-supplied levels.
    pub fn run_tuned<F>(
        &self,
        graph: &Graph,
        tuning: &crate::runtime::artifacts::TuningArtifact,
        work: F,
    ) -> Result<ThreadedRunResult, UnsupportedPolicy>
    where
        F: Fn(NodeId) + Send + Sync,
    {
        assert!(
            tuning.matches_graph(graph.len()),
            "tuning artifact for {} nodes applied to a {}-node graph",
            tuning.graph_nodes,
            graph.len()
        );
        let levels = crate::graph::levels(graph, &tuning.durations_us);
        self.run(graph, levels, work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build as mlp, MlpConfig};
    use crate::models::{self, ModelKind, ModelSize};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::{Duration, Instant};

    #[test]
    fn executes_every_op_exactly_once_in_both_modes() {
        let g = mlp(&MlpConfig::default());
        for mode in DispatchMode::ALL {
            let counter = AtomicU64::new(0);
            let engine = ThreadedGraphi::new(3).with_dispatch(mode);
            let result = engine
                .run(&g, vec![1.0; g.len()], |_n| {
                    counter.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64, "{}", mode.name());
            assert_eq!(result.records.len(), g.len(), "{}", mode.name());
            assert_eq!(result.dispatches, g.len() as u64, "{}", mode.name());
        }
    }

    #[test]
    fn respects_dependencies_under_real_concurrency() {
        // Record completion order with an atomic clock and verify
        // topological consistency — on real threads, with 4 executors,
        // in both dispatch modes.
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        for mode in DispatchMode::ALL {
            let clock = AtomicU64::new(0);
            let stamp: Vec<AtomicU64> = (0..g.len()).map(|_| AtomicU64::new(u64::MAX)).collect();
            let engine = ThreadedGraphi::new(4).with_dispatch(mode);
            engine
                .run(&g, vec![1.0; g.len()], |n| {
                    // simulate a little work to widen race windows
                    for _ in 0..100 {
                        std::hint::spin_loop();
                    }
                    let t = clock.fetch_add(1, Ordering::SeqCst);
                    stamp[n as usize].store(t, Ordering::SeqCst);
                })
                .unwrap();
            for v in 0..g.len() as NodeId {
                for &p in g.preds(v) {
                    let tp = stamp[p as usize].load(Ordering::SeqCst);
                    let tv = stamp[v as usize].load(Ordering::SeqCst);
                    assert!(tp < tv, "{}: dep violated: {p} (t={tp}) vs {v} (t={tv})", mode.name());
                }
            }
        }
    }

    #[test]
    fn decentralized_accounts_steals() {
        // a wide graph on several executors: steal counts must be
        // consistent (≤ dispatches) and every op still runs exactly once
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let counter = AtomicU64::new(0);
        let result = ThreadedGraphi::new(4)
            .run(&g, vec![1.0; g.len()], |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64);
        assert!(result.steals <= result.dispatches);
        // no domain map ⇒ nothing can be accounted as cross-domain
        assert_eq!(result.cross_domain_steals, 0);
    }

    #[test]
    fn numa_map_accounts_cross_domain_steals_consistently() {
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let engine = ThreadedGraphi::new(4).with_numa(DomainMap::new(vec![0, 0, 1, 1], 0));
        let counter = AtomicU64::new(0);
        let result = engine
            .run(&g, vec![1.0; g.len()], |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64);
        assert_eq!(result.records.len(), g.len());
        assert!(result.cross_domain_steals <= result.steals);
    }

    #[test]
    fn with_numa_machine_builds_a_fleet_shaped_map() {
        let snc = crate::cost::machine::Machine::knl7250_snc4();
        let engine = ThreadedGraphi::new(8).with_numa_machine(&snc, 8);
        let map = engine.numa.as_ref().unwrap();
        assert_eq!(map.len(), 8);
        assert!(map.is_multi_domain());
        // and it still executes correctly
        let g = mlp(&MlpConfig::default());
        let r = engine.run(&g, vec![1.0; g.len()], |_| {}).unwrap();
        assert_eq!(r.records.len(), g.len());
    }

    #[test]
    #[should_panic(expected = "one domain per executor")]
    fn mismatched_numa_map_rejected() {
        let _ = ThreadedGraphi::new(4).with_numa(DomainMap::new(vec![0, 1], 0));
    }

    #[test]
    fn idle_fleet_parks_instead_of_spinning() {
        // a pure chain on many executors: all but one executor is idle the
        // whole run, long enough (per-op busy-wait) to walk spin → yield →
        // park. The backoff must actually reach the park stage, and the
        // run must still complete (wakeups not lost).
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let mut prev = b.add("n0", OpKind::Scalar);
        for i in 1..64 {
            let n = b.add(format!("n{i}"), OpKind::Scalar);
            b.depend(prev, n);
            prev = n;
        }
        let g = b.build().unwrap();
        let result = ThreadedGraphi::new(4)
            .run(&g, vec![1.0; g.len()], |_| {
                // ~hundreds of µs of busy work per op so idle executors
                // have time to exhaust the spin and yield budgets
                let t = Instant::now();
                while t.elapsed() < Duration::from_micros(200) {
                    std::hint::spin_loop();
                }
            })
            .unwrap();
        assert_eq!(result.records.len(), g.len());
        assert!(
            result.parks > 0,
            "3 idle executors over a ~13 ms chain must park at least once"
        );
    }

    #[test]
    fn event_recording_captures_parks_and_is_off_by_default() {
        // same chain shape as idle_fleet_parks_instead_of_spinning: the
        // idle executors' parks must show up as events when recording is
        // on, and the sink must not even exist when it is off
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let mut prev = b.add("n0", OpKind::Scalar);
        for i in 1..64 {
            let n = b.add(format!("n{i}"), OpKind::Scalar);
            b.depend(prev, n);
            prev = n;
        }
        let g = b.build().unwrap();
        let spin = |_: NodeId| {
            let t = Instant::now();
            while t.elapsed() < Duration::from_micros(200) {
                std::hint::spin_loop();
            }
        };
        let result =
            ThreadedGraphi::new(4).with_event_recording(true).run(&g, vec![1.0; g.len()], spin).unwrap();
        let parks =
            result.events.iter().filter(|e| e.kind == FleetEventKind::Park).count();
        assert!(parks > 0, "recorded events must include the idle executors' parks");
        // sorted by time (single session: session clock)
        for w in result.events.windows(2) {
            assert!(w[0].t_us <= w[1].t_us);
        }
        let result = ThreadedGraphi::new(4).run(&g, vec![1.0; g.len()], spin).unwrap();
        assert!(result.events.is_empty(), "recording is opt-in");
    }

    #[test]
    fn phased_run_records_mode_switch_events() {
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let src = b.add("src", OpKind::Scalar);
        let mids: Vec<NodeId> = (0..8)
            .map(|i| {
                let m = b.add(format!("m{i}"), OpKind::Scalar);
                b.depend(src, m);
                m
            })
            .collect();
        let _sink = b.add_after("sink", OpKind::Scalar, &mids);
        let g = b.build().unwrap();
        let plan = PhasePlan {
            threshold: 2,
            modes: vec![
                DispatchMode::Centralized,
                DispatchMode::Decentralized,
                DispatchMode::Centralized,
            ],
        };
        let result = ThreadedGraphi::new(3)
            .with_phase_plan(plan)
            .with_event_recording(true)
            .run(&g, vec![1.0; g.len()], |_| {})
            .unwrap();
        let switches: Vec<_> = result
            .events
            .iter()
            .filter(|e| matches!(e.kind, FleetEventKind::ModeSwitch { .. }))
            .collect();
        assert_eq!(switches.len(), 2, "c|d|c boundaries emit two switch events");
        assert!(switches.iter().all(|e| e.executor == FLEET_LANE));
    }

    #[test]
    fn run_tuned_uses_artifact_fleet_and_durations() {
        use crate::runtime::artifacts::{MachineKey, TuningArtifact, TUNING_FORMAT_VERSION};
        let g = mlp(&MlpConfig::default());
        let tuning = TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: "mlp-test".to_string(),
            worker_cores: 64,
            seed: 0,
            machine: MachineKey { cores: 68, numa_domains: 1 },
            graph_nodes: g.len(),
            best: (3, 21),
            best_dispatch: DispatchMode::Decentralized,
            best_makespan_us: 1.0,
            total_profile_iterations: 1,
            durations_us: vec![2.0; g.len()],
            phase_plan: None,
            width_plan: None,
            search_trace: Vec::new(),
        };
        let engine = ThreadedGraphi::from_tuning(&tuning);
        assert_eq!(engine.executors, 3);
        assert_eq!(engine.dispatch, DispatchMode::Decentralized);
        assert_eq!(engine.phase_plan, None);
        let counter = AtomicU64::new(0);
        let result = engine
            .run_tuned(&g, &tuning, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64);
        assert_eq!(result.records.len(), g.len());
    }

    #[test]
    fn from_tuning_adopts_the_artifact_phase_plan() {
        use crate::runtime::artifacts::{MachineKey, TuningArtifact, TUNING_FORMAT_VERSION};
        let g = mlp(&MlpConfig::default());
        let phases = crate::graph::width_phases(&g, 1);
        let plan = PhasePlan::uniform(1, DispatchMode::Decentralized, phases.len());
        let tuning = TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: "mlp-test".to_string(),
            worker_cores: 64,
            seed: 0,
            machine: MachineKey { cores: 68, numa_domains: 1 },
            graph_nodes: g.len(),
            best: (2, 32),
            best_dispatch: DispatchMode::Decentralized,
            best_makespan_us: 1.0,
            total_profile_iterations: 1,
            durations_us: vec![2.0; g.len()],
            phase_plan: Some(plan.clone()),
            width_plan: None,
            search_trace: Vec::new(),
        };
        let engine = ThreadedGraphi::from_tuning(&tuning);
        assert_eq!(engine.phase_plan, Some(plan));
        let result = engine.run_tuned(&g, &tuning, |_| {}).unwrap();
        assert_eq!(result.records.len(), g.len());
    }

    #[test]
    fn unsupported_policy_rejected_with_structured_error() {
        // Fifo/Lifo/Random only ever ordered the PR-1 centralized heap;
        // the session core must refuse them with a typed error (not a
        // panic, not silently running CP-first) that the CLI's error
        // chain can print
        let g = mlp(&MlpConfig::default());
        for policy in [Policy::Fifo, Policy::Lifo, Policy::Random] {
            let engine = ThreadedGraphi { policy, ..ThreadedGraphi::new(2) };
            let err = engine
                .run(&g, vec![1.0; g.len()], |_| {})
                .expect_err("non-CP policy must be refused");
            assert_eq!(err, UnsupportedPolicy { policy });
            assert!(
                err.to_string().contains("not supported by the threaded session core"),
                "{err}"
            );
        }
    }

    #[test]
    fn anti_critical_policy_reverses_dispatch_order() {
        // AntiCritical maps onto the session core by negating levels:
        // a single executor must dispatch lowest-level-first
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        for name in ["a", "b", "c"] {
            b.add(name, OpKind::Scalar);
        }
        let g = b.build().unwrap();
        let levels = vec![5.0, 1.0, 9.0];
        for mode in DispatchMode::ALL {
            let order = std::sync::Mutex::new(Vec::new());
            let engine = ThreadedGraphi {
                policy: Policy::AntiCritical,
                ..ThreadedGraphi::new(1).with_dispatch(mode)
            };
            engine
                .run(&g, levels.clone(), |n| {
                    order.lock().unwrap().push(n);
                })
                .unwrap();
            assert_eq!(order.into_inner().unwrap(), vec![1, 0, 2], "{}", mode.name());
        }
    }

    #[test]
    fn single_executor_works_in_both_modes() {
        let g = mlp(&MlpConfig::default());
        for mode in DispatchMode::ALL {
            let engine = ThreadedGraphi::new(1).with_dispatch(mode);
            let result = engine.run(&g, vec![1.0; g.len()], |_| {}).unwrap();
            assert_eq!(result.records.len(), g.len(), "{}", mode.name());
        }
    }

    #[test]
    fn shared_levels_are_not_copied_per_run() {
        // Arc-typed levels flow through without cloning the slice
        let g = mlp(&MlpConfig::default());
        let levels: Arc<[f64]> = vec![1.0; g.len()].into();
        let engine = ThreadedGraphi::new(2);
        for _ in 0..3 {
            let r = engine.run(&g, Arc::clone(&levels), |_| {}).unwrap();
            assert_eq!(r.records.len(), g.len());
        }
        // borrowed slices still accepted (one copy, at the caller's choice)
        let r = engine.run(&g, &levels[..], |_| {}).unwrap();
        assert_eq!(r.records.len(), g.len());
    }

    #[test]
    fn cp_first_orders_by_level_on_single_executor() {
        // with 1 executor, dispatch order among simultaneously-ready ops
        // follows level priority — in centralized mode via the ready-heap,
        // in decentralized mode via the ascending-key seed order
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let _a = b.add("a", OpKind::Scalar);
        let _bb = b.add("b", OpKind::Scalar);
        let _c = b.add("c", OpKind::Scalar);
        let g = b.build().unwrap();
        // levels make node 2 hottest, then 0, then 1
        let levels = vec![5.0, 1.0, 9.0];
        for mode in DispatchMode::ALL {
            let order = std::sync::Mutex::new(Vec::new());
            ThreadedGraphi::new(1)
                .with_dispatch(mode)
                .run(&g, levels.clone(), |n| {
                    order.lock().unwrap().push(n);
                })
                .unwrap();
            let order = order.into_inner().unwrap();
            assert_eq!(order, vec![2, 0, 1], "{}", mode.name());
        }
    }

    #[test]
    fn phased_run_executes_every_phase_under_its_mode() {
        // 1 → {8 wide} → 1 fan: threshold 2 gives narrow|wide|narrow, and
        // an alternating plan must transition at every boundary while
        // keeping exactly-once + dependency order
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let src = b.add("src", OpKind::Scalar);
        let mids: Vec<NodeId> = (0..8)
            .map(|i| {
                let m = b.add(format!("m{i}"), OpKind::Scalar);
                b.depend(src, m);
                m
            })
            .collect();
        let sink = b.add_after("sink", OpKind::Scalar, &mids);
        let g = b.build().unwrap();
        let phases = crate::graph::width_phases(&g, 2);
        assert_eq!(phases.len(), 3);
        let plan = PhasePlan {
            threshold: 2,
            modes: vec![
                DispatchMode::Centralized,
                DispatchMode::Decentralized,
                DispatchMode::Centralized,
            ],
        };
        let clock = AtomicU64::new(0);
        let stamp: Vec<AtomicU64> = (0..g.len()).map(|_| AtomicU64::new(u64::MAX)).collect();
        let result = ThreadedGraphi::new(3)
            .with_phase_plan(plan)
            .run(&g, vec![1.0; g.len()], |n| {
                let t = clock.fetch_add(1, Ordering::SeqCst);
                stamp[n as usize].store(t, Ordering::SeqCst);
            })
            .unwrap();
        assert_eq!(result.records.len(), g.len());
        assert_eq!(result.dispatches, g.len() as u64);
        assert_eq!(result.mode_switches, 2, "c|d|c transitions at both boundaries");
        // dependency order across the barrier
        for &m in &mids {
            assert!(stamp[src as usize].load(Ordering::SeqCst) < stamp[m as usize].load(Ordering::SeqCst));
            assert!(stamp[m as usize].load(Ordering::SeqCst) < stamp[sink as usize].load(Ordering::SeqCst));
        }
        // records merged onto one monotone timeline (no cross-phase overlap)
        for w in result.records.windows(2) {
            assert!(w[0].start_us <= w[1].start_us);
        }
    }

    #[test]
    #[should_panic(expected = "does not line up")]
    fn mismatched_phase_plan_panics() {
        let g = mlp(&MlpConfig::default());
        let plan = PhasePlan { threshold: 2, modes: vec![DispatchMode::Centralized; 99] };
        let _ = ThreadedGraphi::new(2).with_phase_plan(plan).run(&g, vec![1.0; g.len()], |_| {});
    }
}
