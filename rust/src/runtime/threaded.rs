//! The Graphi engine on *real* host threads, in two dispatch architectures.
//!
//! **Centralized** (§4/§5, PR 1): a scheduler thread (here: the calling
//! thread), a fleet of executor threads, per-executor SPSC operation
//! buffers, and a single bounded MPSC completion queue flowing completions
//! back. Every completion round-trips executor → queue → `DepTracker` →
//! ready-heap → SPSC ring → executor, serializing dispatch on one thread.
//!
//! **Decentralized** (PR 3, the default): the common case never touches a
//! coordinator. Executors share the graph's CSR successor layout through an
//! [`AtomicDepTracker`]; the executor finishing op `n` `fetch_sub`s each
//! successor's remaining-deps counter and pushes newly-ready ops onto its
//! own [`WorkStealDeque`] (packed CP-level keys). Local pops take the LIFO
//! end for cache affinity; idle executors steal the highest-priority
//! exposed entry across victims, preserving §4.3 CP-first semantics (see
//! [`crate::engine::worksteal`] for the full argument). The calling thread
//! degrades to a parker/watchdog: it seeds the source ops, waits for the
//! quiescence signal (raised by whichever executor completes the final
//! op), and collects the trace. Keeping both modes behind
//! [`DispatchMode`] keeps them differentially testable
//! (`tests/differential_engines.rs`).
//!
//! On this repo's 1-core CI machine the fleet cannot show parallel
//! *speedup*; what it demonstrates is that both dispatch paths are real
//! concurrent code producing valid schedules, and the decentralized path
//! is the engine the paper's system would want once op rates outrun a
//! single scheduler core.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::engine::mpsc::MpscQueue;
use crate::engine::policies::Policy;
use crate::engine::ready::{entry_node, pack_entry, DepTracker, ReadySet};
use crate::engine::ring::SpscRing;
use crate::engine::scheduler::IdleBitmap;
use crate::engine::trace::OpRecord;
use crate::engine::worksteal::{self, WorkStealDeque};
use crate::engine::DispatchMode;
use crate::graph::{AtomicDepTracker, Graph, NodeId};

/// Real-threads Graphi configuration.
#[derive(Debug, Clone)]
pub struct ThreadedGraphi {
    /// Executor threads to spawn.
    pub executors: usize,
    /// Ready-op ordering (centralized mode; decentralized dispatch is
    /// CP-first by construction).
    pub policy: Policy,
    /// Per-executor operation buffer depth (§5.2 uses 1; centralized mode).
    pub buffer_depth: usize,
    /// Completion-resolution architecture.
    pub dispatch: DispatchMode,
}

impl ThreadedGraphi {
    pub fn new(executors: usize) -> ThreadedGraphi {
        ThreadedGraphi {
            executors,
            policy: Policy::CriticalPathFirst,
            buffer_depth: 1,
            dispatch: DispatchMode::Decentralized,
        }
    }

    pub fn with_dispatch(mut self, dispatch: DispatchMode) -> ThreadedGraphi {
        self.dispatch = dispatch;
        self
    }

    /// Fleet shape (and dispatch mode) from a persisted tuning artifact.
    pub fn from_tuning(tuning: &crate::runtime::artifacts::TuningArtifact) -> ThreadedGraphi {
        ThreadedGraphi {
            dispatch: tuning.best_dispatch,
            ..ThreadedGraphi::new(tuning.best.0.max(1))
        }
    }
}

/// Result of a threaded run.
#[derive(Debug)]
pub struct ThreadedRunResult {
    /// Wall-clock makespan, µs.
    pub wall_us: f64,
    /// Per-op records (wall-clock µs since run start).
    pub records: Vec<OpRecord>,
    /// Dispatch decisions (centralized: scheduler pushes; decentralized:
    /// local pops + steals).
    pub dispatches: u64,
    /// Decentralized mode: ops acquired by stealing (0 when centralized).
    pub steals: u64,
}

impl ThreadedGraphi {
    /// Execute `graph`, calling `work(node)` for each op on some executor
    /// thread, dependencies respected. `levels` orders ready ops (pass
    /// profiled level values, or unit levels); `Vec` callers move, `Arc`
    /// callers share — no per-run O(nodes) copy either way.
    pub fn run<F>(&self, graph: &Graph, levels: impl Into<Arc<[f64]>>, work: F) -> ThreadedRunResult
    where
        F: Fn(NodeId) + Send + Sync,
    {
        let levels: Arc<[f64]> = levels.into();
        assert_eq!(levels.len(), graph.len());
        assert!(self.executors >= 1);
        match self.dispatch {
            DispatchMode::Centralized => self.run_centralized(graph, &levels, &work),
            DispatchMode::Decentralized => self.run_decentralized(graph, &levels, &work),
        }
    }

    /// The PR-1 architecture: central scheduler on the calling thread.
    fn run_centralized<F>(&self, graph: &Graph, levels: &Arc<[f64]>, work: &F) -> ThreadedRunResult
    where
        F: Fn(NodeId) + Send + Sync,
    {
        let n_exec = self.executors;
        let op_rings: Vec<SpscRing<NodeId>> =
            (0..n_exec).map(|_| SpscRing::new(self.buffer_depth)).collect();
        // one completion queue shared by all executors; sized for the whole
        // graph so a push can never fail (each node completes exactly once)
        let done_q: MpscQueue<(u32, NodeId)> = MpscQueue::new(graph.len() + 1);
        let shutdown = AtomicBool::new(false);
        let t0 = Instant::now();

        let mut all_records: Vec<Vec<OpRecord>> = Vec::new();
        let mut dispatches = 0u64;

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n_exec);
            for e in 0..n_exec {
                let op_ring = &op_rings[e];
                let done_q = &done_q;
                let shutdown = &shutdown;
                let work = &work;
                handles.push(scope.spawn(move || {
                    // Algorithm 2: poll own buffer, execute, report back.
                    let mut records = Vec::new();
                    loop {
                        if let Some(node) = op_ring.pop() {
                            let start = t0.elapsed().as_secs_f64() * 1e6;
                            work(node);
                            let end = t0.elapsed().as_secs_f64() * 1e6;
                            records.push(OpRecord {
                                node,
                                executor: e as u32,
                                start_us: start,
                                end_us: end,
                            });
                            // report completion to the shared queue (§4.4)
                            done_q
                                .push((e as u32, node))
                                .expect("completion queue sized for whole graph");
                        } else if shutdown.load(Ordering::Acquire) {
                            return records;
                        } else {
                            std::hint::spin_loop();
                            std::thread::yield_now();
                        }
                    }
                }));
            }

            // ---- scheduler (Algorithm 1) on the calling thread ----
            // Executor availability is tracked as a bitmap (§5.2); a bit is
            // set when the executor's depth-bounded operation buffer has
            // room. With depth 1 this is the paper's "buffer at most one
            // operation" behaviour: the scheduler can stage the next op
            // while the current one runs, and no deeper (avoiding the load
            // imbalance §5.2 observed with larger buffers).
            let mut deps = DepTracker::new(graph);
            let mut ready = ReadySet::new(self.policy, Arc::clone(levels), 0);
            let mut available = IdleBitmap::new(n_exec);
            let mut inflight = vec![0usize; n_exec];
            let mut completions: Vec<(u32, NodeId)> = Vec::with_capacity(n_exec * 2 + 8);
            for s in deps.sources() {
                ready.push(s);
            }
            while !deps.is_done() {
                // drain the shared completion queue in one batch — a single
                // acquire load when idle, no per-executor scan
                completions.clear();
                done_q.pop_batch(&mut completions, usize::MAX);
                for &(e, node) in completions.iter() {
                    let e = e as usize;
                    inflight[e] -= 1;
                    if inflight[e] == self.buffer_depth - 1 && !available.is_idle(e) {
                        available.set_idle(e);
                    }
                    deps.complete(graph, node, |n| ready.push(n));
                }
                // dispatch: max-level ops → first available executor
                // (bit-scan), filling its buffer through one batched push
                let mut progressed = false;
                while !ready.is_empty() && available.any_idle() {
                    let e = available.first_idle().unwrap();
                    let room = self.buffer_depth - inflight[e];
                    let mut feed = std::iter::from_fn(|| ready.pop()).take(room);
                    let pushed = op_rings[e].push_batch(&mut feed);
                    debug_assert!(pushed > 0, "availability bit ⇒ ring space");
                    dispatches += pushed as u64;
                    progressed = true;
                    inflight[e] += pushed;
                    if inflight[e] >= self.buffer_depth {
                        available.set_busy(e);
                    }
                }
                // On the paper's machine the scheduler owns a reserved core
                // and busy-polls (§5.2). On an oversubscribed host (e.g. a
                // 1-core CI box) pure spinning starves the executor threads
                // of their timeslice — yield whenever no dispatch happened
                // so completions can actually arrive (§Perf L3 iteration 1:
                // 2.9 s → ~ms-scale for a ~1.5k-op graph).
                if !progressed {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            }
            shutdown.store(true, Ordering::Release);
            for h in handles {
                all_records.push(h.join().expect("executor thread panicked"));
            }
        });

        let mut records: Vec<OpRecord> = all_records.into_iter().flatten().collect();
        records.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        let wall_us = t0.elapsed().as_secs_f64() * 1e6;
        ThreadedRunResult { wall_us, records, dispatches, steals: 0 }
    }

    /// PR-3 architecture: executor-side successor resolution + CP-aware
    /// work stealing. No scheduler loop exists; the calling thread only
    /// seeds the sources, parks until the quiescence flag (raised by the
    /// executor that completes the final op), and merges the trace.
    fn run_decentralized<F>(&self, graph: &Graph, levels: &[f64], work: &F) -> ThreadedRunResult
    where
        F: Fn(NodeId) + Send + Sync,
    {
        // decentralized dispatch is CP-first by construction and buffers
        // through the deques, so `policy`/`buffer_depth` have no effect
        // here — surface a misconfiguration instead of ignoring it
        debug_assert!(
            matches!(self.policy, Policy::CriticalPathFirst),
            "policy {:?} is ignored by DispatchMode::Decentralized (CP-first by construction); \
             use DispatchMode::Centralized for alternative policies",
            self.policy
        );
        let n_exec = self.executors;
        let deps = AtomicDepTracker::new(graph);
        // each deque could in the worst case hold every op; sizing them so
        // guarantees pushes never fail (each op is enqueued exactly once)
        let deques: Vec<WorkStealDeque> =
            (0..n_exec).map(|_| WorkStealDeque::new(graph.len())).collect();
        let done = AtomicBool::new(false);

        // Startup (coordinator duty #1): seed sources round-robin, in
        // ascending key order so every deque's LIFO end starts at its
        // highest-priority seed.
        let mut sources = graph.sources();
        sources.sort_unstable_by_key(|&s| pack_entry(levels[s as usize], s));
        for (i, &s) in sources.iter().enumerate() {
            deques[i % n_exec]
                .push(pack_entry(levels[s as usize], s))
                .expect("deque sized for the whole graph");
        }
        let t0 = Instant::now();

        let mut all_records: Vec<Vec<OpRecord>> = Vec::new();
        let mut dispatches = 0u64;
        let mut steals = 0u64;

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n_exec);
            for e in 0..n_exec {
                let deques = &deques[..];
                let deps = &deps;
                let done = &done;
                let work = &work;
                handles.push(scope.spawn(move || {
                    let mut records = Vec::new();
                    let mut my_dispatches = 0u64;
                    let mut my_steals = 0u64;
                    let mut batch: Vec<u64> = Vec::new();
                    let mut spins = 0u32;
                    loop {
                        match worksteal::acquire(deques, e) {
                            Some((key, stolen)) => {
                                spins = 0;
                                my_dispatches += 1;
                                if stolen {
                                    my_steals += 1;
                                }
                                let node = entry_node(key);
                                let start = t0.elapsed().as_secs_f64() * 1e6;
                                work(node);
                                let end = t0.elapsed().as_secs_f64() * 1e6;
                                records.push(OpRecord {
                                    node,
                                    executor: e as u32,
                                    start_us: start,
                                    end_us: end,
                                });
                                // The tentpole: resolve successors right
                                // here — fetch_sub over the CSR slice, push
                                // the newly-ready ops onto the own deque
                                // (ascending, so the LIFO end is the
                                // batch's highest-level op).
                                batch.clear();
                                let last = deps.complete(graph, node, |s| {
                                    batch.push(pack_entry(levels[s as usize], s));
                                });
                                batch.sort_unstable();
                                for &k in &batch {
                                    deques[e].push(k).expect("deque sized for the whole graph");
                                }
                                if last {
                                    // quiescence: this completion was the
                                    // graph's final op
                                    done.store(true, Ordering::Release);
                                }
                            }
                            None => {
                                if done.load(Ordering::Acquire) {
                                    return (records, my_dispatches, my_steals);
                                }
                                spins += 1;
                                if spins < 64 {
                                    std::hint::spin_loop();
                                } else {
                                    spins = 0;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                }));
            }
            // Parker/watchdog: joining *is* the quiescence wait — each
            // executor returns only after the done flag is raised.
            for h in handles {
                let (records, d, s) = h.join().expect("executor thread panicked");
                all_records.push(records);
                dispatches += d;
                steals += s;
            }
        });
        debug_assert!(deps.is_done(), "threads exited with unexecuted ops");

        let mut records: Vec<OpRecord> = all_records.into_iter().flatten().collect();
        records.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        let wall_us = t0.elapsed().as_secs_f64() * 1e6;
        ThreadedRunResult { wall_us, records, dispatches, steals }
    }

    /// Execute `graph` with critical-path levels derived from a tuning
    /// artifact's profiled per-op duration table (§4.2 fed back into the
    /// real-threads engine), instead of caller-supplied levels.
    pub fn run_tuned<F>(
        &self,
        graph: &Graph,
        tuning: &crate::runtime::artifacts::TuningArtifact,
        work: F,
    ) -> ThreadedRunResult
    where
        F: Fn(NodeId) + Send + Sync,
    {
        assert!(
            tuning.matches_graph(graph.len()),
            "tuning artifact for {} nodes applied to a {}-node graph",
            tuning.graph_nodes,
            graph.len()
        );
        let levels = crate::graph::levels(graph, &tuning.durations_us);
        self.run(graph, levels, work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build as mlp, MlpConfig};
    use crate::models::{self, ModelKind, ModelSize};
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_every_op_exactly_once_in_both_modes() {
        let g = mlp(&MlpConfig::default());
        for mode in DispatchMode::ALL {
            let counter = AtomicU64::new(0);
            let engine = ThreadedGraphi::new(3).with_dispatch(mode);
            let result = engine.run(&g, vec![1.0; g.len()], |_n| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64, "{}", mode.name());
            assert_eq!(result.records.len(), g.len(), "{}", mode.name());
            assert_eq!(result.dispatches, g.len() as u64, "{}", mode.name());
        }
    }

    #[test]
    fn respects_dependencies_under_real_concurrency() {
        // Record completion order with an atomic clock and verify
        // topological consistency — on real threads, with 4 executors,
        // in both dispatch modes.
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        for mode in DispatchMode::ALL {
            let clock = AtomicU64::new(0);
            let stamp: Vec<AtomicU64> = (0..g.len()).map(|_| AtomicU64::new(u64::MAX)).collect();
            let engine = ThreadedGraphi::new(4).with_dispatch(mode);
            engine.run(&g, vec![1.0; g.len()], |n| {
                // simulate a little work to widen race windows
                for _ in 0..100 {
                    std::hint::spin_loop();
                }
                let t = clock.fetch_add(1, Ordering::SeqCst);
                stamp[n as usize].store(t, Ordering::SeqCst);
            });
            for v in 0..g.len() as NodeId {
                for &p in g.preds(v) {
                    let tp = stamp[p as usize].load(Ordering::SeqCst);
                    let tv = stamp[v as usize].load(Ordering::SeqCst);
                    assert!(tp < tv, "{}: dep violated: {p} (t={tp}) vs {v} (t={tv})", mode.name());
                }
            }
        }
    }

    #[test]
    fn decentralized_accounts_steals() {
        // a wide graph on several executors: steal counts must be
        // consistent (≤ dispatches) and every op still runs exactly once
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let counter = AtomicU64::new(0);
        let result = ThreadedGraphi::new(4).run(&g, vec![1.0; g.len()], |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64);
        assert!(result.steals <= result.dispatches);
    }

    #[test]
    fn run_tuned_uses_artifact_fleet_and_durations() {
        use crate::runtime::artifacts::{MachineKey, TuningArtifact, TUNING_FORMAT_VERSION};
        let g = mlp(&MlpConfig::default());
        let tuning = TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: "mlp-test".to_string(),
            worker_cores: 64,
            seed: 0,
            machine: MachineKey { cores: 68, numa_domains: 1 },
            graph_nodes: g.len(),
            best: (3, 21),
            best_dispatch: DispatchMode::Decentralized,
            best_makespan_us: 1.0,
            total_profile_iterations: 1,
            durations_us: vec![2.0; g.len()],
            search_trace: Vec::new(),
        };
        let engine = ThreadedGraphi::from_tuning(&tuning);
        assert_eq!(engine.executors, 3);
        assert_eq!(engine.dispatch, DispatchMode::Decentralized);
        let counter = AtomicU64::new(0);
        let result = engine.run_tuned(&g, &tuning, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64);
        assert_eq!(result.records.len(), g.len());
    }

    #[test]
    fn single_executor_works_in_both_modes() {
        let g = mlp(&MlpConfig::default());
        for mode in DispatchMode::ALL {
            let engine = ThreadedGraphi::new(1).with_dispatch(mode);
            let result = engine.run(&g, vec![1.0; g.len()], |_| {});
            assert_eq!(result.records.len(), g.len(), "{}", mode.name());
        }
    }

    #[test]
    fn shared_levels_are_not_copied_per_run() {
        // Arc-typed levels flow through without cloning the slice
        let g = mlp(&MlpConfig::default());
        let levels: Arc<[f64]> = vec![1.0; g.len()].into();
        let engine = ThreadedGraphi::new(2);
        for _ in 0..3 {
            let r = engine.run(&g, Arc::clone(&levels), |_| {});
            assert_eq!(r.records.len(), g.len());
        }
        // borrowed slices still accepted (one copy, at the caller's choice)
        let r = engine.run(&g, &levels[..], |_| {});
        assert_eq!(r.records.len(), g.len());
    }

    #[test]
    fn cp_first_orders_by_level_on_single_executor() {
        // with 1 executor, dispatch order among simultaneously-ready ops
        // follows level priority — in centralized mode via the ready-heap,
        // in decentralized mode via the ascending-key seed order
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let _a = b.add("a", OpKind::Scalar);
        let _bb = b.add("b", OpKind::Scalar);
        let _c = b.add("c", OpKind::Scalar);
        let g = b.build().unwrap();
        // levels make node 2 hottest, then 0, then 1
        let levels = vec![5.0, 1.0, 9.0];
        for mode in DispatchMode::ALL {
            let order = std::sync::Mutex::new(Vec::new());
            ThreadedGraphi::new(1).with_dispatch(mode).run(&g, levels.clone(), |n| {
                order.lock().unwrap().push(n);
            });
            let order = order.into_inner().unwrap();
            assert_eq!(order, vec![2, 0, 1], "{}", mode.name());
        }
    }
}
