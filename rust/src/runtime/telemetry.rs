//! Serve-mode continuous telemetry: a bounded ring of recent session
//! records plus periodic aggregate snapshots.
//!
//! Long serve runs cannot keep every session record (a full Chrome trace
//! of a million-request run would grow without bound), so observability
//! splits in two:
//!
//! - **The ring** ([`TelemetryRing`]) keeps the most recent
//!   [`SessionSample`]s — one small fixed-size struct per finished request
//!   (completion time, latency, outcome class, model index) — in a
//!   fixed-capacity circular buffer. New samples overwrite the oldest once
//!   the ring is full; a lifetime counter keeps totals exact even after
//!   overwrites. Memory is `capacity × sizeof(SessionSample)`, independent
//!   of run length.
//! - **Snapshots** ([`TelemetrySnapshot`]) are cheap aggregates computed
//!   from the ring plus the fleet's monotone counters at a sampling
//!   instant: requests/s, p50/p99 latency per outcome class (over the ring
//!   window), queue depth, in-flight count, and steal/park rates (from
//!   counter deltas against the previous snapshot). `graphi serve
//!   --telemetry-every-ms N` prints one line per interval and the final
//!   report carries the collected snapshots (dumpable as JSON).
//!
//! The ring is a single mutex over a flat `Vec` — pushes happen once per
//! *session* (not per op), so at serving rates where lock contention here
//! would matter, the fleet's admission queue saturates first.

use std::sync::Mutex;

use crate::runtime::fleet::FleetTotals;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Terminal class of a served request, including admission sheds (which
/// never become fleet sessions but still burn client-visible latency).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutcomeClass {
    Ok,
    Failed,
    Cancelled,
    Deadline,
    Shed,
}

impl OutcomeClass {
    pub const ALL: [OutcomeClass; 5] = [
        OutcomeClass::Ok,
        OutcomeClass::Failed,
        OutcomeClass::Cancelled,
        OutcomeClass::Deadline,
        OutcomeClass::Shed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OutcomeClass::Ok => "ok",
            OutcomeClass::Failed => "failed",
            OutcomeClass::Cancelled => "cancelled",
            OutcomeClass::Deadline => "deadline",
            OutcomeClass::Shed => "shed",
        }
    }
}

/// One finished request, as the ring remembers it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionSample {
    /// Completion instant, µs on the serve run's clock (the fleet epoch).
    pub t_us: f64,
    /// Client-observed latency (admission wait + execution), µs.
    pub latency_us: f64,
    pub class: OutcomeClass,
    /// Index into the serve run's model zoo.
    pub model: u8,
}

#[derive(Debug, Default)]
struct RingState {
    buf: Vec<SessionSample>,
    /// Next overwrite position once `buf` is at capacity.
    next: usize,
    /// Lifetime samples pushed (≥ `buf.len()`).
    total: u64,
}

/// Bounded in-memory ring of recent session samples. See the module docs
/// for the design.
#[derive(Debug)]
pub struct TelemetryRing {
    cap: usize,
    state: Mutex<RingState>,
}

impl TelemetryRing {
    pub fn new(capacity: usize) -> TelemetryRing {
        let cap = capacity.max(1);
        TelemetryRing {
            cap,
            state: Mutex::new(RingState { buf: Vec::with_capacity(cap), next: 0, total: 0 }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Record one finished request, overwriting the oldest sample when
    /// the ring is full.
    pub fn push(&self, sample: SessionSample) {
        let mut s = self.state.lock().unwrap();
        if s.buf.len() < self.cap {
            s.buf.push(sample);
        } else {
            let at = s.next;
            s.buf[at] = sample;
            s.next = (at + 1) % self.cap;
        }
        s.total += 1;
    }

    /// Samples currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lifetime samples pushed, including overwritten ones.
    pub fn total(&self) -> u64 {
        self.state.lock().unwrap().total
    }

    /// Copy of the ring's current contents (unordered).
    pub fn samples(&self) -> Vec<SessionSample> {
        self.state.lock().unwrap().buf.clone()
    }

    /// Aggregate the ring and the fleet counters into a snapshot at
    /// `now_us`. `prev` (the previous snapshot, if any) turns monotone
    /// counters into interval rates; without it, rates are lifetime
    /// averages over `[0, now_us]`.
    pub fn snapshot(
        &self,
        now_us: f64,
        totals: FleetTotals,
        queue_waiting: u64,
        in_flight: usize,
        prev: Option<&TelemetrySnapshot>,
    ) -> TelemetrySnapshot {
        let (samples, total) = {
            let s = self.state.lock().unwrap();
            (s.buf.clone(), s.total)
        };
        // interval basis: since the previous snapshot, or since t=0
        let (t_base, total_base, steals_base, parks_base, sheds_base) = match prev {
            Some(p) => {
                (p.t_us, p.total_sessions, p.totals.steals, p.totals.parks, p.totals.sessions_shed)
            }
            None => (0.0, 0, 0, 0, 0),
        };
        // a degenerate interval — ≤ 1 µs (one clock tick), zero (the
        // always-emitted final snapshot of an instant drain lands on the
        // previous snapshot's timestamp), negative, or NaN — carries no
        // rate information: report 0.0 instead of dividing into Inf/NaN
        // or an absurd ~1e9× spike (the old `.max(1e-9)` clamp)
        let dt_us = now_us - t_base;
        let rate = |delta: u64| if dt_us > 1.0 { delta as f64 / (dt_us / 1e6) } else { 0.0 };
        let rps = rate(total.saturating_sub(total_base));
        let steal_rate = rate(totals.steals.saturating_sub(steals_base));
        let park_rate = rate(totals.parks.saturating_sub(parks_base));
        let shed_rate = rate(totals.sessions_shed.saturating_sub(sheds_base));
        let mut per_class = Vec::new();
        for class in OutcomeClass::ALL {
            let lat: Vec<f64> =
                samples.iter().filter(|s| s.class == class).map(|s| s.latency_us).collect();
            if let Some(summary) = Summary::from_samples_opt(&lat) {
                per_class.push((class, summary));
            }
        }
        TelemetrySnapshot {
            t_us: now_us,
            window_n: samples.len(),
            total_sessions: total,
            rps,
            per_class,
            queue_waiting,
            in_flight,
            steal_rate,
            park_rate,
            shed_rate,
            totals,
        }
    }
}

/// Aggregate view of the serve run at one instant. Latency percentiles
/// cover the ring's window (recent sessions); rates cover the interval
/// since the previous snapshot.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    /// Snapshot instant, µs on the serve run's clock.
    pub t_us: f64,
    /// Samples in the ring window.
    pub window_n: usize,
    /// Lifetime finished requests.
    pub total_sessions: u64,
    /// Finished requests per second over the interval.
    pub rps: f64,
    /// Ring-window latency summary per outcome class (classes with ≥ 1
    /// sample only, so every percentile is finite by construction).
    pub per_class: Vec<(OutcomeClass, Summary)>,
    /// Requests waiting in the admission queue right now.
    pub queue_waiting: u64,
    /// Requests admitted but not yet finished.
    pub in_flight: usize,
    /// Steals per second over the interval.
    pub steal_rate: f64,
    /// Parks per second over the interval.
    pub park_rate: f64,
    /// Requests shed at admission per second over the interval — the
    /// overload signal ([`FleetTotals::sessions_shed`] delta).
    pub shed_rate: f64,
    /// Raw fleet counter snapshot (the next snapshot's delta basis).
    pub totals: FleetTotals,
}

impl TelemetrySnapshot {
    /// One compact human line, the `--telemetry-every-ms` output format.
    pub fn render_line(&self) -> String {
        let mut line = format!(
            "telemetry t={:7.2}s done={} rps={:7.1} q={} inflight={} steal/s={:.0} park/s={:.0}",
            self.t_us / 1e6,
            self.total_sessions,
            self.rps,
            self.queue_waiting,
            self.in_flight,
            self.steal_rate,
            self.park_rate,
        );
        if self.shed_rate > 0.0 || self.totals.sessions_shed > 0 {
            line.push_str(&format!(" shed/s={:.0}", self.shed_rate));
        }
        for (class, s) in &self.per_class {
            line.push_str(&format!(
                " {}[n={} p50={} p99={}]",
                class.name(),
                s.n,
                crate::util::fmt_us(s.p50),
                crate::util::fmt_us(s.p99),
            ));
        }
        line
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("t_s", self.t_us / 1e6)
            .set("window_n", self.window_n)
            .set("total_sessions", self.total_sessions)
            .set("rps", self.rps)
            .set("queue_waiting", self.queue_waiting)
            .set("in_flight", self.in_flight)
            .set("steal_rate", self.steal_rate)
            .set("park_rate", self.park_rate)
            .set("shed_rate", self.shed_rate)
            .set("sessions_shed", self.totals.sessions_shed);
        let mut classes = Json::obj();
        for (class, s) in &self.per_class {
            let mut c = Json::obj();
            c.set("n", s.n)
                .set("mean_us", s.mean)
                .set("p50_us", s.p50)
                .set("p90_us", s.p90)
                .set("p99_us", s.p99)
                .set("max_us", s.max);
            classes.set(class.name(), c);
        }
        doc.set("latency_by_class", classes);
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t_us: f64, latency_us: f64, class: OutcomeClass) -> SessionSample {
        SessionSample { t_us, latency_us, class, model: 0 }
    }

    #[test]
    fn ring_is_bounded_and_counts_lifetime_total() {
        let ring = TelemetryRing::new(4);
        for i in 0..10 {
            ring.push(sample(i as f64, 100.0 + i as f64, OutcomeClass::Ok));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.total(), 10);
        // the survivors are the last 4 pushed
        let mut latencies: Vec<f64> = ring.samples().iter().map(|s| s.latency_us).collect();
        latencies.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(latencies, vec![106.0, 107.0, 108.0, 109.0]);
    }

    #[test]
    fn snapshot_of_empty_ring_is_finite() {
        let ring = TelemetryRing::new(8);
        let snap = ring.snapshot(1_000_000.0, FleetTotals::default(), 0, 0, None);
        assert_eq!(snap.window_n, 0);
        assert_eq!(snap.total_sessions, 0);
        assert_eq!(snap.rps, 0.0);
        assert!(snap.per_class.is_empty(), "no class summaries without samples");
        assert!(snap.steal_rate.is_finite() && snap.park_rate.is_finite());
        let line = snap.render_line();
        assert!(line.contains("rps"));
    }

    #[test]
    fn snapshot_aggregates_per_class_with_finite_percentiles() {
        let ring = TelemetryRing::new(64);
        // one class with a single sample, one with identical samples
        ring.push(sample(10.0, 500.0, OutcomeClass::Failed));
        for i in 0..10 {
            ring.push(sample(20.0 + i as f64, 250.0, OutcomeClass::Ok));
        }
        let snap = ring.snapshot(2_000_000.0, FleetTotals::default(), 3, 2, None);
        assert_eq!(snap.window_n, 11);
        assert_eq!(snap.queue_waiting, 3);
        assert_eq!(snap.in_flight, 2);
        let ok = snap.per_class.iter().find(|(c, _)| *c == OutcomeClass::Ok).unwrap();
        assert_eq!(ok.1.n, 10);
        assert_eq!(ok.1.p50, 250.0);
        assert_eq!(ok.1.p99, 250.0);
        let failed = snap.per_class.iter().find(|(c, _)| *c == OutcomeClass::Failed).unwrap();
        assert_eq!(failed.1.n, 1);
        assert!(failed.1.p50.is_finite() && failed.1.p99.is_finite());
        assert_eq!(failed.1.p99, 500.0);
        // no samples in the remaining classes → absent, not NaN
        assert!(!snap.per_class.iter().any(|(c, _)| *c == OutcomeClass::Cancelled));
    }

    #[test]
    fn interval_rates_use_the_previous_snapshot_as_basis() {
        let ring = TelemetryRing::new(64);
        for i in 0..10 {
            ring.push(sample(i as f64 * 1000.0, 100.0, OutcomeClass::Ok));
        }
        let t1 = FleetTotals { steals: 100, parks: 50, ..FleetTotals::default() };
        let first = ring.snapshot(1_000_000.0, t1, 0, 0, None);
        assert!((first.rps - 10.0).abs() < 1e-9, "10 sessions over 1s");
        assert!((first.steal_rate - 100.0).abs() < 1e-9);
        for i in 0..20 {
            ring.push(sample(1_000_000.0 + i as f64, 100.0, OutcomeClass::Ok));
        }
        let t2 =
            FleetTotals { steals: 160, parks: 80, sessions_shed: 40, ..FleetTotals::default() };
        let second = ring.snapshot(3_000_000.0, t2, 0, 0, Some(&first));
        assert!((second.rps - 10.0).abs() < 1e-9, "20 more sessions over 2s");
        assert!((second.steal_rate - 30.0).abs() < 1e-9, "60 more steals over 2s");
        assert!((second.park_rate - 15.0).abs() < 1e-9, "30 more parks over 2s");
        assert!((second.shed_rate - 20.0).abs() < 1e-9, "40 sheds over 2s");
        assert!(second.render_line().contains("shed/s=20"), "{}", second.render_line());
    }

    /// Satellite regression (fails before the degenerate-interval guard):
    /// a snapshot taken ≤ 1 clock tick after its basis — or the final
    /// snapshot of an instant drain, which lands on the same timestamp —
    /// must report zero rates, not Inf/NaN and not the ~1e9× spike the
    /// old `dt.max(1e-9)` clamp produced from nonzero counter deltas.
    #[test]
    fn degenerate_intervals_report_zero_rates() {
        let ring = TelemetryRing::new(8);
        for i in 0..5 {
            ring.push(sample(i as f64, 100.0, OutcomeClass::Ok));
        }
        let t1 = FleetTotals { steals: 10, parks: 5, ..FleetTotals::default() };
        let first = ring.snapshot(1_000_000.0, t1, 0, 0, None);
        // zero-width interval with fresh counter deltas
        let t2 = FleetTotals { steals: 50, parks: 25, sessions_shed: 7, ..FleetTotals::default() };
        ring.push(sample(1_000_000.0, 100.0, OutcomeClass::Ok));
        let same_instant = ring.snapshot(1_000_000.0, t2, 0, 0, Some(&first));
        for (name, rate) in [
            ("rps", same_instant.rps),
            ("steal_rate", same_instant.steal_rate),
            ("park_rate", same_instant.park_rate),
            ("shed_rate", same_instant.shed_rate),
        ] {
            assert!(rate.is_finite(), "{name} must be finite on a zero interval");
            assert_eq!(rate, 0.0, "{name} must be 0 on a zero interval, got {rate}");
        }
        // one-tick interval: still degenerate
        let one_tick = ring.snapshot(1_000_001.0, t2, 0, 0, Some(&first));
        assert_eq!(one_tick.rps, 0.0, "≤1µs interval has no rate information");
        // a clock that stepped backwards must not produce negative rates
        let backwards = ring.snapshot(999_000.0, t2, 0, 0, Some(&first));
        assert_eq!(backwards.steal_rate, 0.0);
        // totals still flow through untouched for the next delta basis
        assert_eq!(same_instant.totals.steals, 50);
        // and a healthy interval still reports real rates
        let healthy = ring.snapshot(3_000_000.0, t2, 0, 0, Some(&first));
        assert!((healthy.steal_rate - 20.0).abs() < 1e-9, "40 steals over 2s");
    }

    #[test]
    fn snapshot_json_round_trips() {
        let ring = TelemetryRing::new(8);
        ring.push(sample(10.0, 123.0, OutcomeClass::Ok));
        let snap = ring.snapshot(1_000_000.0, FleetTotals::default(), 1, 1, None);
        let text = snap.to_json().to_string_pretty();
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("total_sessions").unwrap().as_f64().unwrap(), 1.0);
        let ok = doc.get("latency_by_class").unwrap().get("ok").unwrap();
        assert_eq!(ok.get("p99_us").unwrap().as_f64().unwrap(), 123.0);
    }
}
