//! PJRT client wrapper: load HLO text → compile → execute.
//!
//! Follows /opt/xla-example/load_hlo exactly: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `compile` → `execute`. The artifacts are lowered with
//! `return_tuple=True`, so every output is a 1-level tuple.
//!
//! The real backend needs the `xla` crate, which the offline build image
//! does not ship; it is therefore gated behind the **`pjrt` cargo
//! feature** (enable it only with a vendored `xla`). Without the feature
//! this module compiles a stub with the identical API whose constructors
//! return a descriptive error, so the rest of the crate — including the
//! threaded scheduler the training driver feeds — builds and tests
//! dependency-free.

use std::path::Path;

use crate::util::error::Result;
#[cfg(feature = "pjrt")]
use crate::util::error::Context;

use super::artifacts::{ArtifactSet, Manifest};

/// A PJRT CPU runtime.
pub struct PjrtRuntime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(not(feature = "pjrt"))]
    _private: (),
}

/// A compiled module ready to execute.
pub struct LoadedModule {
    pub name: String,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Create the CPU client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one artifact module.
    pub fn load(&self, set: &ArtifactSet, name: &str) -> Result<LoadedModule> {
        let manifest = set.module(name)?.clone();
        let path = set.path_of(&manifest);
        self.load_path(&path, manifest)
    }

    /// Load and compile an HLO text file directly.
    pub fn load_path(&self, path: &Path, manifest: Manifest) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModule { name: manifest.name.clone(), exe, manifest })
    }
}

#[cfg(feature = "pjrt")]
impl LoadedModule {
    /// Execute with f32 input tensors (shapes per the manifest); returns
    /// the flattened f32 outputs in tuple order.
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        crate::ensure!(
            inputs.len() == self.manifest.inputs.len(),
            "module {} takes {} inputs, got {}",
            self.name,
            self.manifest.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.manifest.inputs) {
            let expect: usize = shape.iter().product();
            crate::ensure!(
                data.len() == expect,
                "input shape {:?} needs {} elements, got {}",
                shape,
                expect,
                data.len()
            );
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .context("reshaping input literal")?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.to_tuple().context("untupling result")?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().context("reading output literal")?);
        }
        Ok(out)
    }
}

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str = "graphi was built without the `pjrt` feature \
    (the vendored `xla` crate is required for real PJRT execution)";

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Stub: always fails — rebuild with `--features pjrt`.
    pub fn cpu() -> Result<PjrtRuntime> {
        crate::bail!("{NO_PJRT}")
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Stub: always fails — rebuild with `--features pjrt`.
    pub fn load(&self, set: &ArtifactSet, name: &str) -> Result<LoadedModule> {
        let _ = set.module(name)?; // still validate the manifest lookup
        crate::bail!("{NO_PJRT}")
    }

    /// Stub: always fails — rebuild with `--features pjrt`.
    pub fn load_path(&self, _path: &Path, _manifest: Manifest) -> Result<LoadedModule> {
        crate::bail!("{NO_PJRT}")
    }
}

#[cfg(not(feature = "pjrt"))]
impl LoadedModule {
    /// Stub: always fails — rebuild with `--features pjrt`.
    pub fn run_f32(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        crate::bail!("{NO_PJRT}")
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    //! Execution against real artifacts is covered by `rust/tests/`
    //! integration tests (they require `make artifacts`). Here we test the
    //! pure-rust fallback path: building a computation with XlaBuilder and
    //! running it through the same client, which exercises the PJRT wiring
    //! without Python.
    use super::*;

    #[test]
    fn pjrt_cpu_roundtrip_via_builder() {
        let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
        assert!(!rt.platform().is_empty());
        let builder = xla::XlaBuilder::new("t");
        let c = builder.constant_r1(&[1.0f32, 2.0]).unwrap();
        let comp = (c + builder.constant_r0(1.0f32).unwrap()).unwrap().build().unwrap();
        let exe = rt.client.compile(&comp).unwrap();
        let out = exe.execute::<xla::Literal>(&[]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![2.0, 3.0]);
    }

    #[test]
    fn run_f32_validates_arity_and_shape() {
        // synthesize a LoadedModule via a builder computation + fake manifest
        let rt = PjrtRuntime::cpu().unwrap();
        let builder = xla::XlaBuilder::new("t2");
        let shape = xla::Shape::array::<f32>(vec![2, 2]);
        let p = builder.parameter_s(0, &shape, "p").unwrap();
        let comp = builder
            .tuple(&[p.add_(&p).unwrap()])
            .unwrap()
            .build()
            .unwrap();
        let exe = rt.client.compile(&comp).unwrap();
        let module = LoadedModule {
            name: "double".into(),
            exe,
            manifest: Manifest {
                name: "double".into(),
                file: String::new(),
                inputs: vec![vec![2, 2]],
                outputs: vec![vec![2, 2]],
                meta: Default::default(),
            },
        };
        // wrong arity
        assert!(module.run_f32(&[]).is_err());
        // wrong element count
        assert!(module.run_f32(&[vec![1.0; 3]]).is_err());
        // correct
        let out = module.run_f32(&[vec![1.0, 2.0, 3.0, 4.0]]).unwrap();
        assert_eq!(out[0], vec![2.0, 4.0, 6.0, 8.0]);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = PjrtRuntime::cpu().unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "error should name the feature");
    }
}
