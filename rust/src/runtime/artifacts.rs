//! Artifact discovery.
//!
//! `make artifacts` produces `artifacts/*.hlo.txt` plus a
//! `manifest.json` describing each module's entry shapes, so the Rust side
//! can size its buffers without re-deriving anything from Python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// One module's manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub name: String,
    pub file: String,
    /// Input tensor shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shapes (the module returns a tuple).
    pub outputs: Vec<Vec<usize>>,
    /// Free-form metadata (hyper-parameters the module was lowered with).
    pub meta: BTreeMap<String, f64>,
}

/// A directory of compiled artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub modules: Vec<Manifest>,
}

/// Artifact errors.
#[derive(Debug)]
pub enum ArtifactError {
    MissingDir(String),
    MissingManifest(String),
    BadManifest(String),
    UnknownModule(String, String),
    Io(std::io::Error),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::MissingDir(d) => {
                write!(f, "artifact directory {d} not found — run `make artifacts` first")
            }
            ArtifactError::MissingManifest(d) => {
                write!(f, "manifest.json missing in {d} — run `make artifacts`")
            }
            ArtifactError::BadManifest(m) => write!(f, "malformed manifest: {m}"),
            ArtifactError::UnknownModule(name, have) => {
                write!(f, "unknown module `{name}` (have: {have})")
            }
            ArtifactError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> ArtifactError {
        ArtifactError::Io(e)
    }
}

/// Default artifact directory: `$GRAPHI_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("GRAPHI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl ArtifactSet {
    /// Load the manifest from a directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactSet, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(ArtifactError::MissingDir(dir.display().to_string()));
        }
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.is_file() {
            return Err(ArtifactError::MissingManifest(dir.display().to_string()));
        }
        let text = std::fs::read_to_string(&manifest_path)?;
        let doc = json::parse(&text).map_err(|e| ArtifactError::BadManifest(e.to_string()))?;
        let modules = parse_manifest(&doc)?;
        Ok(ArtifactSet { dir, modules })
    }

    /// Find a module by name.
    pub fn module(&self, name: &str) -> Result<&Manifest, ArtifactError> {
        self.modules.iter().find(|m| m.name == name).ok_or_else(|| {
            ArtifactError::UnknownModule(
                name.to_string(),
                self.modules.iter().map(|m| m.name.clone()).collect::<Vec<_>>().join(", "),
            )
        })
    }

    /// Absolute path of a module's HLO text.
    pub fn path_of(&self, m: &Manifest) -> PathBuf {
        self.dir.join(&m.file)
    }
}

fn parse_manifest(doc: &Json) -> Result<Vec<Manifest>, ArtifactError> {
    let bad = |msg: &str| ArtifactError::BadManifest(msg.to_string());
    let modules = doc
        .get("modules")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| bad("missing `modules` array"))?;
    let mut out = Vec::new();
    for m in modules {
        let name = m
            .get("name")
            .and_then(|x| x.as_str())
            .ok_or_else(|| bad("module missing `name`"))?
            .to_string();
        let file = m
            .get("file")
            .and_then(|x| x.as_str())
            .ok_or_else(|| bad("module missing `file`"))?
            .to_string();
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>, ArtifactError> {
            let arr = m
                .get(key)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| bad(&format!("module missing `{key}`")))?;
            arr.iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .ok_or_else(|| bad("shape must be an array"))?
                        .iter()
                        .map(|d| {
                            d.as_f64()
                                .map(|x| x as usize)
                                .ok_or_else(|| bad("dimension must be a number"))
                        })
                        .collect()
                })
                .collect()
        };
        let mut meta = BTreeMap::new();
        if let Some(Json::Obj(entries)) = m.get("meta") {
            for (k, v) in entries {
                if let Some(x) = v.as_f64() {
                    meta.insert(k.clone(), x);
                }
            }
        }
        out.push(Manifest { name, file, inputs: shapes("inputs")?, outputs: shapes("outputs")?, meta });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "modules": [
        {
          "name": "train_step",
          "file": "train_step.hlo.txt",
          "inputs": [[256, 1024], [8, 16]],
          "outputs": [[1], [256, 1024]],
          "meta": {"hidden": 256, "vocab": 256}
        }
      ]
    }"#;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("graphi-artifact-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn load_and_lookup() {
        let dir = tmpdir("ok");
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let set = ArtifactSet::load(&dir).unwrap();
        let m = set.module("train_step").unwrap();
        assert_eq!(m.inputs[0], vec![256, 1024]);
        assert_eq!(m.meta["vocab"], 256.0);
        assert!(set.path_of(m).ends_with("train_step.hlo.txt"));
        assert!(matches!(
            set.module("nope").unwrap_err(),
            ArtifactError::UnknownModule(_, _)
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_reported() {
        let err = ArtifactSet::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn malformed_manifest_reported() {
        let dir = tmpdir("bad");
        std::fs::write(dir.join("manifest.json"), "{\"modules\": [{}]}").unwrap();
        assert!(matches!(
            ArtifactSet::load(&dir).unwrap_err(),
            ArtifactError::BadManifest(_)
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
