//! Artifact discovery and tuning-artifact persistence.
//!
//! `make artifacts` produces `artifacts/*.hlo.txt` plus a
//! `manifest.json` describing each module's entry shapes, so the Rust side
//! can size its buffers without re-deriving anything from Python.
//!
//! The same directory also holds **tuning artifacts**
//! (`tuning/<tag>.tuning.json`): the autotuner's winning parallel setting,
//! its per-op duration table, and the full search trace, versioned so a
//! later run can load the result instead of re-searching
//! ([`autotune_or_load`]). A corrupt, missing, stale, or
//! version-mismatched artifact degrades to a fresh search — never a panic.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::cost::machine::Machine;
use crate::engine::autotune::{AutotuneReport, Autotuner};
use crate::engine::ready::MAX_WIDTH;
use crate::engine::{DispatchMode, PhasePlan, SimEnv, WidthPlan};
use crate::graph::op::OpClass;
use crate::graph::Graph;
use crate::util::json::{self, Json};

/// One module's manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub name: String,
    pub file: String,
    /// Input tensor shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shapes (the module returns a tuple).
    pub outputs: Vec<Vec<usize>>,
    /// Free-form metadata (hyper-parameters the module was lowered with).
    pub meta: BTreeMap<String, f64>,
}

/// A directory of compiled artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub modules: Vec<Manifest>,
}

/// Artifact errors.
#[derive(Debug)]
pub enum ArtifactError {
    MissingDir(String),
    MissingManifest(String),
    BadManifest(String),
    UnknownModule(String, String),
    BadTuning(String),
    TuningVersion { found: u64, expected: u64 },
    Io(std::io::Error),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::MissingDir(d) => {
                write!(f, "artifact directory {d} not found — run `make artifacts` first")
            }
            ArtifactError::MissingManifest(d) => {
                write!(f, "manifest.json missing in {d} — run `make artifacts`")
            }
            ArtifactError::BadManifest(m) => write!(f, "malformed manifest: {m}"),
            ArtifactError::UnknownModule(name, have) => {
                write!(f, "unknown module `{name}` (have: {have})")
            }
            ArtifactError::BadTuning(m) => write!(f, "malformed tuning artifact: {m}"),
            ArtifactError::TuningVersion { found, expected } => {
                write!(f, "tuning artifact format v{found}, this build reads v{expected}")
            }
            ArtifactError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> ArtifactError {
        ArtifactError::Io(e)
    }
}

/// Default artifact directory: `$GRAPHI_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("GRAPHI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl ArtifactSet {
    /// Load the manifest from a directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactSet, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(ArtifactError::MissingDir(dir.display().to_string()));
        }
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.is_file() {
            return Err(ArtifactError::MissingManifest(dir.display().to_string()));
        }
        let text = std::fs::read_to_string(&manifest_path)?;
        let doc = json::parse(&text).map_err(|e| ArtifactError::BadManifest(e.to_string()))?;
        let modules = parse_manifest(&doc)?;
        Ok(ArtifactSet { dir, modules })
    }

    /// Find a module by name.
    pub fn module(&self, name: &str) -> Result<&Manifest, ArtifactError> {
        self.modules.iter().find(|m| m.name == name).ok_or_else(|| {
            ArtifactError::UnknownModule(
                name.to_string(),
                self.modules.iter().map(|m| m.name.clone()).collect::<Vec<_>>().join(", "),
            )
        })
    }

    /// Absolute path of a module's HLO text.
    pub fn path_of(&self, m: &Manifest) -> PathBuf {
        self.dir.join(&m.file)
    }
}

fn parse_manifest(doc: &Json) -> Result<Vec<Manifest>, ArtifactError> {
    let bad = |msg: &str| ArtifactError::BadManifest(msg.to_string());
    let modules = doc
        .get("modules")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| bad("missing `modules` array"))?;
    let mut out = Vec::new();
    for m in modules {
        let name = m
            .get("name")
            .and_then(|x| x.as_str())
            .ok_or_else(|| bad("module missing `name`"))?
            .to_string();
        let file = m
            .get("file")
            .and_then(|x| x.as_str())
            .ok_or_else(|| bad("module missing `file`"))?
            .to_string();
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>, ArtifactError> {
            let arr = m
                .get(key)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| bad(&format!("module missing `{key}`")))?;
            arr.iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .ok_or_else(|| bad("shape must be an array"))?
                        .iter()
                        .map(|d| {
                            d.as_f64()
                                .map(|x| x as usize)
                                .ok_or_else(|| bad("dimension must be a number"))
                        })
                        .collect()
                })
                .collect()
        };
        let mut meta = BTreeMap::new();
        if let Some(Json::Obj(entries)) = m.get("meta") {
            for (k, v) in entries {
                if let Some(x) = v.as_f64() {
                    meta.insert(k.clone(), x);
                }
            }
        }
        out.push(Manifest { name, file, inputs: shapes("inputs")?, outputs: shapes("outputs")?, meta });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Tuning artifacts
// ---------------------------------------------------------------------------

/// Format version of persisted tuning artifacts. Bump on any schema change;
/// readers reject other versions (and the caller re-searches).
///
/// v2 (PR 3): added the per-machine key (`machine_cores`,
/// `machine_numa_domains`) and the dispatch-mode axis (`best_dispatch`,
/// per-measurement `dispatch`). v3 (PR 4): added the optional per-phase
/// dispatch plan (`phase_threshold` + `phase_modes`). v4 (PR 10): added
/// the optional per-op-class gang-width plan (`widths`). v1–v3 artifacts
/// degrade to a fresh search.
pub const TUNING_FORMAT_VERSION: u64 = 4;

/// The hardware identity a tuning result is valid for: physical core count
/// and sub-NUMA clustering mode (quadrant = 1 domain, SNC-4 = 4). One
/// tuning directory can serve a heterogeneous fleet — each machine loads
/// only artifacts whose key matches its own, and degrades to a fresh
/// search otherwise, exactly like a stale or foreign-version file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachineKey {
    pub cores: usize,
    pub numa_domains: usize,
}

impl MachineKey {
    pub fn of(machine: &Machine) -> MachineKey {
        MachineKey { cores: machine.cores, numa_domains: machine.numa_domains }
    }
}

impl std::fmt::Display for MachineKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}c/{}d", self.cores, self.numa_domains)
    }
}

/// One halving round of the persisted search trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningRound {
    /// Per-candidate iterations added in this round.
    pub iterations: usize,
    /// `(executors, threads_per, dispatch, cumulative mean makespan µs)`
    /// for every candidate alive in this round, best first.
    pub measurements: Vec<(usize, usize, DispatchMode, f64)>,
}

/// A persisted autotuning result: the winning parallel setting, the per-op
/// duration table behind the scheduler's level values, and the search
/// trace that produced them.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningArtifact {
    pub version: u64,
    /// What was tuned, e.g. `lstm-small` or `train_step`.
    pub tag: String,
    pub worker_cores: usize,
    /// Seed of the environment the search ran in.
    pub seed: u64,
    /// The machine the search ran on; a different machine key invalidates
    /// the artifact (its winner was tuned for other hardware).
    pub machine: MachineKey,
    /// Node count of the tuned graph — a mismatching graph invalidates
    /// the artifact (durations are indexed by node id).
    pub graph_nodes: usize,
    /// Winning `(executors, threads_per)`.
    pub best: (usize, usize),
    /// Winning dispatch architecture.
    pub best_dispatch: DispatchMode,
    /// Per-phase dispatch plan, when the autotuner's flip search found one
    /// that beats the uniform winner (v3). `None` = run uniformly under
    /// `best_dispatch`.
    pub phase_plan: Option<PhasePlan>,
    /// Per-op-class gang-width plan, when the autotuner's width search was
    /// enabled and found one that beats uniform width 1 (v4). `None` = run
    /// every op at width 1 (no gangs).
    pub width_plan: Option<WidthPlan>,
    pub best_makespan_us: f64,
    /// Profiling iterations the search spent.
    pub total_profile_iterations: usize,
    /// Per-op duration estimates at the winning team size, µs.
    pub durations_us: Vec<f64>,
    pub search_trace: Vec<TuningRound>,
}

/// Machine-agnostic on-disk location of a tuning artifact inside an
/// artifact directory: `<dir>/tuning/<tag>.tuning.json`. Kept for
/// single-machine setups and as the fallback read location; prefer
/// [`tuning_path_for`], which keys the filename by machine so a shared
/// tuning directory converges instead of different machines clobbering
/// each other's results.
pub fn tuning_path(dir: impl AsRef<Path>, tag: &str) -> PathBuf {
    dir.as_ref().join("tuning").join(format!("{tag}.tuning.json"))
}

/// Machine-keyed artifact location:
/// `<dir>/tuning/<tag>.<cores>c<domains>d.tuning.json`. Machines with
/// different keys read and write different files, so one tuning directory
/// can genuinely serve a heterogeneous fleet (the in-file `machine` field
/// stays as defense against hand-copied artifacts).
pub fn tuning_path_for(dir: impl AsRef<Path>, tag: &str, machine: &MachineKey) -> PathBuf {
    dir.as_ref().join("tuning").join(format!(
        "{tag}.{}c{}d.tuning.json",
        machine.cores, machine.numa_domains
    ))
}

impl TuningArtifact {
    /// Package an autotune report for persistence. The environment supplies
    /// the seed and the machine key the result is stamped with.
    pub fn from_report(
        tag: &str,
        graph_nodes: usize,
        env: &SimEnv,
        tuner: &Autotuner,
        report: &AutotuneReport,
    ) -> TuningArtifact {
        TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: tag.to_string(),
            worker_cores: tuner.worker_cores,
            seed: env.seed,
            machine: MachineKey::of(&env.cost.machine),
            graph_nodes,
            best: report.best,
            best_dispatch: report.best_dispatch,
            phase_plan: report.phase_plan.clone(),
            width_plan: report.width_plan.clone(),
            best_makespan_us: report.best_makespan_us,
            total_profile_iterations: report.total_profile_iterations,
            durations_us: report.durations_us.clone(),
            search_trace: report
                .rounds
                .iter()
                .map(|r| TuningRound {
                    iterations: r.iterations,
                    measurements: r
                        .measurements
                        .iter()
                        .map(|m| (m.executors, m.threads_per, m.dispatch, m.mean_makespan_us))
                        .collect(),
                })
                .collect(),
        }
    }

    /// Is this artifact applicable to a graph with `nodes` operations?
    pub fn matches_graph(&self, nodes: usize) -> bool {
        self.graph_nodes == nodes && self.durations_us.len() == nodes
    }

    /// Was this artifact tuned on hardware matching `machine`?
    pub fn matches_machine(&self, machine: &Machine) -> bool {
        self.machine == MachineKey::of(machine)
    }

    /// Critical-path level values from the persisted duration table.
    pub fn levels(&self, graph: &Graph) -> Vec<f64> {
        assert!(
            self.matches_graph(graph.len()),
            "tuning artifact for {} nodes applied to a {}-node graph",
            self.graph_nodes,
            graph.len()
        );
        crate::graph::levels(graph, &self.durations_us)
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("kind", "graphi-tuning")
            .set("version", self.version)
            .set("tag", self.tag.as_str())
            .set("worker_cores", self.worker_cores)
            .set("seed", self.seed)
            .set("machine_cores", self.machine.cores)
            .set("machine_numa_domains", self.machine.numa_domains)
            .set("graph_nodes", self.graph_nodes)
            .set("best_executors", self.best.0)
            .set("best_threads_per", self.best.1)
            .set("best_dispatch", self.best_dispatch.name())
            .set("best_makespan_us", self.best_makespan_us)
            .set("total_profile_iterations", self.total_profile_iterations)
            .set(
                "durations_us",
                Json::Arr(self.durations_us.iter().map(|&d| Json::Num(d)).collect()),
            );
        if let Some(plan) = &self.phase_plan {
            doc.set("phase_threshold", plan.threshold).set(
                "phase_modes",
                Json::Arr(plan.modes.iter().map(|m| Json::from(m.name())).collect()),
            );
        }
        if let Some(plan) = &self.width_plan {
            let mut widths = Json::obj();
            for class in OpClass::ALL {
                widths.set(class.name(), plan.width_for(class) as u64);
            }
            doc.set("widths", widths);
        }
        let trace: Vec<Json> = self
            .search_trace
            .iter()
            .map(|round| {
                let mut r = Json::obj();
                r.set("iterations", round.iterations);
                let ms: Vec<Json> = round
                    .measurements
                    .iter()
                    .map(|&(e, t, dispatch, mean)| {
                        let mut m = Json::obj();
                        m.set("executors", e)
                            .set("threads_per", t)
                            .set("dispatch", dispatch.name())
                            .set("mean_makespan_us", mean);
                        m
                    })
                    .collect();
                r.set("measurements", Json::Arr(ms));
                r
            })
            .collect();
        doc.set("search_trace", Json::Arr(trace));
        doc
    }

    pub fn from_json(doc: &Json) -> Result<TuningArtifact, ArtifactError> {
        let bad = |msg: &str| ArtifactError::BadTuning(msg.to_string());
        let num = |key: &str| -> Result<f64, ArtifactError> {
            doc.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| bad(&format!("missing numeric `{key}`")))
        };
        let version = num("version")? as u64;
        if version != TUNING_FORMAT_VERSION {
            return Err(ArtifactError::TuningVersion {
                found: version,
                expected: TUNING_FORMAT_VERSION,
            });
        }
        let tag = doc
            .get("tag")
            .and_then(|v| v.as_str())
            .ok_or_else(|| bad("missing `tag`"))?
            .to_string();
        let durations_us: Vec<f64> = doc
            .get("durations_us")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing `durations_us`"))?
            .iter()
            .map(|d| d.as_f64().ok_or_else(|| bad("non-numeric duration")))
            .collect::<Result<_, _>>()?;
        // A NaN duration would poison every critical-path level computed
        // from the table; a negative one would invert CP ordering. Both
        // mean the file is damaged — reject rather than clamp (unlike the
        // live profiler, which degrades its own noisy estimates in place).
        if durations_us.iter().any(|d| !d.is_finite() || *d < 0.0) {
            return Err(bad("non-finite or negative duration"));
        }
        let dispatch_of = |v: Option<&Json>| -> Result<DispatchMode, ArtifactError> {
            v.and_then(|d| d.as_str())
                .and_then(DispatchMode::parse)
                .ok_or_else(|| bad("missing or unknown `dispatch` mode"))
        };
        let mut search_trace = Vec::new();
        if let Some(rounds) = doc.get("search_trace").and_then(|v| v.as_arr()) {
            for round in rounds {
                let iterations = round
                    .get("iterations")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| bad("round missing `iterations`"))?
                    as usize;
                let mut measurements = Vec::new();
                for m in round
                    .get("measurements")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| bad("round missing `measurements`"))?
                {
                    let field = |key: &str| -> Result<f64, ArtifactError> {
                        m.get(key)
                            .and_then(|v| v.as_f64())
                            .ok_or_else(|| bad(&format!("measurement missing `{key}`")))
                    };
                    measurements.push((
                        field("executors")? as usize,
                        field("threads_per")? as usize,
                        dispatch_of(m.get("dispatch"))?,
                        field("mean_makespan_us")?,
                    ));
                }
                search_trace.push(TuningRound { iterations, measurements });
            }
        }
        let phase_plan = match (doc.get("phase_threshold"), doc.get("phase_modes")) {
            (None, None) => None,
            (Some(t), Some(ms)) => {
                let threshold = t
                    .as_f64()
                    .ok_or_else(|| bad("non-numeric `phase_threshold`"))?
                    as usize;
                let modes: Vec<DispatchMode> = ms
                    .as_arr()
                    .ok_or_else(|| bad("`phase_modes` must be an array"))?
                    .iter()
                    .map(|m| {
                        m.as_str()
                            .and_then(DispatchMode::parse)
                            .ok_or_else(|| bad("unknown mode in `phase_modes`"))
                    })
                    .collect::<Result<_, _>>()?;
                if modes.is_empty() || threshold == 0 {
                    return Err(bad("degenerate phase plan"));
                }
                Some(PhasePlan { threshold, modes })
            }
            _ => return Err(bad("phase_threshold and phase_modes must appear together")),
        };
        let width_plan = match doc.get("widths") {
            None => None,
            Some(Json::Obj(entries)) => {
                let mut plan = WidthPlan::uniform(1);
                for (name, v) in entries {
                    let class = OpClass::ALL
                        .into_iter()
                        .find(|c| c.name() == name.as_str())
                        .ok_or_else(|| bad(&format!("unknown op class `{name}` in `widths`")))?;
                    let w = v
                        .as_f64()
                        .ok_or_else(|| bad(&format!("non-numeric width for `{name}`")))?;
                    if !w.is_finite() || w.fract() != 0.0 || w < 1.0 || w > MAX_WIDTH as f64 {
                        return Err(bad(&format!(
                            "width {w} for `{name}` outside 1..={MAX_WIDTH}"
                        )));
                    }
                    plan.set(class, w as u32);
                }
                Some(plan)
            }
            Some(_) => return Err(bad("`widths` must be an object")),
        };
        let artifact = TuningArtifact {
            version,
            tag,
            worker_cores: num("worker_cores")? as usize,
            seed: num("seed")? as u64,
            machine: MachineKey {
                cores: num("machine_cores")? as usize,
                numa_domains: num("machine_numa_domains")? as usize,
            },
            graph_nodes: num("graph_nodes")? as usize,
            best: (num("best_executors")? as usize, num("best_threads_per")? as usize),
            best_dispatch: dispatch_of(doc.get("best_dispatch"))?,
            phase_plan,
            width_plan,
            best_makespan_us: num("best_makespan_us")?,
            total_profile_iterations: num("total_profile_iterations")? as usize,
            durations_us,
            search_trace,
        };
        if artifact.best.0 == 0 || artifact.best.1 == 0 {
            return Err(bad("degenerate best configuration"));
        }
        if artifact.durations_us.len() != artifact.graph_nodes {
            return Err(bad("duration table does not cover the graph"));
        }
        Ok(artifact)
    }

    /// Persist to `path`, creating parent directories.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), ArtifactError> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Load from `path`. Missing files surface as `Io`, garbage as
    /// `BadTuning`, schema drift as `TuningVersion` — callers treat all
    /// three as "search fresh".
    pub fn load(path: impl AsRef<Path>) -> Result<TuningArtifact, ArtifactError> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let doc = json::parse(&text).map_err(|e| ArtifactError::BadTuning(e.to_string()))?;
        Self::from_json(&doc)
    }
}

/// Where a loaded-or-searched tuning result came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneOutcome {
    /// A valid persisted artifact matched the graph; no search ran.
    LoadedFromDisk,
    /// The search ran (no artifact, or it was corrupt/stale/foreign) and
    /// the result was persisted.
    FreshSearch,
}

/// Load a tuning artifact from `path` if it is valid for `graph` *and*
/// was tuned on hardware matching `env`'s machine key, otherwise run
/// `tuner`'s successive-halving search and persist the result. Never
/// panics on a bad artifact — that is the degrade path, and a mismatched
/// machine key degrades exactly like a stale or foreign-version file (one
/// tuning directory can serve a heterogeneous fleet).
pub fn autotune_or_load(
    path: impl AsRef<Path>,
    tag: &str,
    tuner: &Autotuner,
    graph: &Graph,
    env: &SimEnv,
) -> (TuningArtifact, TuneOutcome) {
    let path = path.as_ref();
    match TuningArtifact::load(path) {
        Ok(artifact)
            if artifact.matches_graph(graph.len())
                && artifact.matches_machine(&env.cost.machine) =>
        {
            return (artifact, TuneOutcome::LoadedFromDisk);
        }
        Ok(artifact) if !artifact.matches_machine(&env.cost.machine) => {
            crate::log_warn!(
                "tuning artifact {} was tuned on {} but this machine is {}; re-searching",
                path.display(),
                artifact.machine,
                MachineKey::of(&env.cost.machine)
            );
        }
        Ok(artifact) => {
            crate::log_warn!(
                "tuning artifact {} covers {} nodes but the graph has {}; re-searching",
                path.display(),
                artifact.graph_nodes,
                graph.len()
            );
        }
        Err(ArtifactError::Io(_)) => {} // absent: the common first-run case
        Err(e) => {
            crate::log_warn!("ignoring tuning artifact {}: {e}", path.display());
        }
    }
    let report = tuner.search(graph, env);
    let artifact = TuningArtifact::from_report(tag, graph.len(), env, tuner, &report);
    if let Err(e) = artifact.save(path) {
        crate::log_warn!("failed to persist tuning artifact {}: {e}", path.display());
    }
    (artifact, TuneOutcome::FreshSearch)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "modules": [
        {
          "name": "train_step",
          "file": "train_step.hlo.txt",
          "inputs": [[256, 1024], [8, 16]],
          "outputs": [[1], [256, 1024]],
          "meta": {"hidden": 256, "vocab": 256}
        }
      ]
    }"#;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("graphi-artifact-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn load_and_lookup() {
        let dir = tmpdir("ok");
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let set = ArtifactSet::load(&dir).unwrap();
        let m = set.module("train_step").unwrap();
        assert_eq!(m.inputs[0], vec![256, 1024]);
        assert_eq!(m.meta["vocab"], 256.0);
        assert!(set.path_of(m).ends_with("train_step.hlo.txt"));
        assert!(matches!(
            set.module("nope").unwrap_err(),
            ArtifactError::UnknownModule(_, _)
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_reported() {
        let err = ArtifactSet::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn malformed_manifest_reported() {
        let dir = tmpdir("bad");
        std::fs::write(dir.join("manifest.json"), "{\"modules\": [{}]}").unwrap();
        assert!(matches!(
            ArtifactSet::load(&dir).unwrap_err(),
            ArtifactError::BadManifest(_)
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn sample_tuning() -> TuningArtifact {
        TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: "lstm-small".to_string(),
            worker_cores: 64,
            seed: 42,
            machine: MachineKey { cores: 68, numa_domains: 1 },
            graph_nodes: 4,
            best: (8, 8),
            best_dispatch: DispatchMode::Decentralized,
            phase_plan: Some(PhasePlan {
                threshold: 8,
                modes: vec![DispatchMode::Centralized, DispatchMode::Decentralized],
            }),
            width_plan: Some({
                let mut plan = WidthPlan::uniform(1);
                plan.set(OpClass::Gemm, 4);
                plan.set(OpClass::Conv, 2);
                plan
            }),
            best_makespan_us: 1234.5,
            total_profile_iterations: 25,
            durations_us: vec![1.5, 2.25, 0.125, 7.0],
            search_trace: vec![
                TuningRound {
                    iterations: 1,
                    measurements: vec![
                        (8, 8, DispatchMode::Decentralized, 1250.0),
                        (4, 16, DispatchMode::Centralized, 1400.0),
                    ],
                },
                TuningRound {
                    iterations: 2,
                    measurements: vec![(8, 8, DispatchMode::Decentralized, 1234.5)],
                },
            ],
        }
    }

    #[test]
    fn tuning_artifact_json_roundtrip_is_exact() {
        let a = sample_tuning();
        let back = TuningArtifact::from_json(&json::parse(&a.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn tuning_artifact_save_load_roundtrip() {
        let dir = tmpdir("tuning-ok");
        let path = tuning_path(&dir, "lstm-small");
        let a = sample_tuning();
        a.save(&path).unwrap();
        let back = TuningArtifact::load(&path).unwrap();
        assert_eq!(back, a);
        assert!(back.matches_graph(4));
        assert!(!back.matches_graph(5));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tuning_artifact_missing_is_io_error() {
        assert!(matches!(
            TuningArtifact::load("/definitely/not/here.tuning.json").unwrap_err(),
            ArtifactError::Io(_)
        ));
    }

    #[test]
    fn tuning_artifact_corrupt_is_bad_tuning() {
        let dir = tmpdir("tuning-corrupt");
        let path = dir.join("x.tuning.json");
        std::fs::write(&path, "{not json").unwrap();
        assert!(matches!(
            TuningArtifact::load(&path).unwrap_err(),
            ArtifactError::BadTuning(_)
        ));
        // current version but nothing else: passes the version gate, then
        // fails on the missing payload
        std::fs::write(&path, format!("{{\"version\": {TUNING_FORMAT_VERSION}}}")).unwrap();
        assert!(matches!(
            TuningArtifact::load(&path).unwrap_err(),
            ArtifactError::BadTuning(_)
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn machine_keyed_paths_do_not_collide() {
        let a = MachineKey { cores: 68, numa_domains: 1 };
        let b = MachineKey { cores: 28, numa_domains: 4 };
        let pa = tuning_path_for("d", "t", &a);
        assert_ne!(pa, tuning_path_for("d", "t", &b));
        assert!(pa.to_string_lossy().ends_with("t.68c1d.tuning.json"), "{}", pa.display());
        // distinct from the machine-agnostic legacy location
        assert_ne!(pa, tuning_path("d", "t"));
    }

    #[test]
    fn machine_key_gates_artifact_reuse() {
        let a = sample_tuning();
        let quadrant = Machine::knl7250();
        assert_eq!(a.machine, MachineKey::of(&quadrant));
        assert!(a.matches_machine(&quadrant));
        // same part in SNC-4 (different NUMA layout) must not reuse it
        assert!(!a.matches_machine(&Machine::knl7250_snc4()));
        // neither must a differently-sized part
        assert!(!a.matches_machine(&Machine::skylake8180()));
        assert_eq!(format!("{}", a.machine), "68c/1d");
    }

    #[test]
    fn v1_artifact_without_machine_key_rejected() {
        // a v1-shaped document (no machine key, no dispatch fields) must
        // fail to parse — the version gate fires first
        let mut doc = sample_tuning().to_json();
        doc.set("version", 1u64);
        let err = TuningArtifact::from_json(&doc).unwrap_err();
        assert!(matches!(err, ArtifactError::TuningVersion { found: 1, .. }));
    }

    #[test]
    fn v2_artifact_without_phase_fields_degrades() {
        // a v2 document (pre-phase-plan schema) must be rejected by the
        // version gate so callers re-search and re-stamp a v4 file — the
        // same degrade path as v1 and corrupt artifacts
        let mut doc = sample_tuning().to_json();
        doc.set("version", 2u64);
        let err = TuningArtifact::from_json(&doc).unwrap_err();
        assert!(matches!(err, ArtifactError::TuningVersion { found: 2, expected: 4 }));
    }

    #[test]
    fn v3_artifact_without_width_fields_degrades() {
        // a v3 document (pre-width-plan schema) degrades identically: the
        // version gate fires before any payload parsing
        let mut doc = sample_tuning().to_json();
        doc.set("version", 3u64);
        let err = TuningArtifact::from_json(&doc).unwrap_err();
        assert!(matches!(err, ArtifactError::TuningVersion { found: 3, expected: 4 }));
    }

    #[test]
    fn artifact_without_width_plan_roundtrips_with_absent_key() {
        // None serializes as an *absent* `widths` key (not null or an
        // all-ones object), and parses back to None
        let a = TuningArtifact { width_plan: None, ..sample_tuning() };
        let text = a.to_json().to_string_pretty();
        assert!(!text.contains("\"widths\""));
        let back = TuningArtifact::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn corrupt_width_plans_are_bad_tuning() {
        let widths = |entries: &[(&str, f64)]| {
            Json::Obj(entries.iter().map(|&(k, v)| (k.to_string(), Json::Num(v))).collect())
        };
        // unknown class name
        let mut doc = sample_tuning().to_json();
        doc.set("widths", widths(&[("warp", 2.0)]));
        assert!(matches!(
            TuningArtifact::from_json(&doc).unwrap_err(),
            ArtifactError::BadTuning(_)
        ));
        // zero, oversized, and fractional widths — a hand-edited file must
        // never smuggle an out-of-range gang width into the fleet
        for w in [0.0, (MAX_WIDTH + 1) as f64, 2.5, f64::NAN] {
            let mut doc = sample_tuning().to_json();
            doc.set("widths", widths(&[("gemm", w)]));
            assert!(
                matches!(TuningArtifact::from_json(&doc).unwrap_err(), ArtifactError::BadTuning(_)),
                "width {w} must be rejected"
            );
        }
        // widths must be an object, not an array
        let mut doc = sample_tuning().to_json();
        doc.set("widths", Json::Arr(vec![Json::Num(2.0)]));
        assert!(matches!(
            TuningArtifact::from_json(&doc).unwrap_err(),
            ArtifactError::BadTuning(_)
        ));
    }

    #[test]
    fn non_finite_or_negative_durations_are_bad_tuning() {
        // the duration table feeds critical-path levels; a damaged file
        // must be rejected, not clamped like live profiler noise
        for poison in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.5] {
            let mut doc = sample_tuning().to_json();
            doc.set(
                "durations_us",
                Json::Arr(vec![Json::Num(1.0), Json::Num(poison), Json::Num(3.0), Json::Num(4.0)]),
            );
            assert!(
                matches!(TuningArtifact::from_json(&doc).unwrap_err(), ArtifactError::BadTuning(_)),
                "duration {poison} must be rejected"
            );
        }
    }

    #[test]
    fn artifact_without_phase_plan_roundtrips_with_absent_keys() {
        // None serializes as *absent* keys (not null), and parses back
        let a = TuningArtifact { phase_plan: None, ..sample_tuning() };
        let text = a.to_json().to_string_pretty();
        assert!(!text.contains("phase_threshold"));
        assert!(!text.contains("phase_modes"));
        let back = TuningArtifact::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn half_specified_phase_plan_is_corrupt() {
        // phase_threshold without phase_modes (or vice versa) is a
        // hand-edited file — reject it as BadTuning, never panic
        let mut doc = TuningArtifact { phase_plan: None, ..sample_tuning() }.to_json();
        doc.set("phase_threshold", 4u64);
        let err = TuningArtifact::from_json(&doc).unwrap_err();
        assert!(matches!(err, ArtifactError::BadTuning(_)));
        // unknown mode names are corrupt too
        let mut doc = sample_tuning().to_json();
        doc.set(
            "phase_modes",
            crate::util::json::Json::Arr(vec![crate::util::json::Json::from("psychic")]),
        );
        assert!(matches!(
            TuningArtifact::from_json(&doc).unwrap_err(),
            ArtifactError::BadTuning(_)
        ));
    }

    #[test]
    fn tuning_artifact_future_version_rejected() {
        let dir = tmpdir("tuning-version");
        let path = dir.join("x.tuning.json");
        let mut doc = sample_tuning().to_json();
        doc.set("version", TUNING_FORMAT_VERSION + 1);
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        assert!(matches!(
            TuningArtifact::load(&path).unwrap_err(),
            ArtifactError::TuningVersion { .. }
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tuning_levels_follow_duration_table() {
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let x = b.add("x", OpKind::Scalar);
        let y = b.add("y", OpKind::Scalar);
        b.depend(x, y);
        b.add("z", OpKind::Scalar);
        b.add("w", OpKind::Scalar);
        let g = b.build().unwrap();
        let a = TuningArtifact { durations_us: vec![3.0, 2.0, 1.0, 4.0], ..sample_tuning() };
        assert_eq!(a.levels(&g), vec![5.0, 2.0, 1.0, 4.0]);
    }
}
