//! Persistent executor fleets and multi-graph serving **sessions**.
//!
//! Until PR 5 the threaded runtime spawned and joined a scoped thread
//! fleet inside every [`crate::runtime::ThreadedGraphi::run`] and executed
//! exactly one graph per fleet lifetime. That reproduces Fig. 5, but it is
//! the wrong shape for serving: Opara (arXiv:2312.10351) shows concurrent
//! inference streams are where operator-level scheduling pays off, and Liu
//! et al. (arXiv:1810.08955) show a *shared* worker pool under admission
//! control is what keeps many small concurrent graphs from strangling each
//! other. This module splits the two lifetimes apart:
//!
//! * a [`Fleet`] spawns its executor threads **once** (plus one scheduler
//!   thread in centralized mode), parks them on the
//!   [`crate::engine::backoff`] eventcount when idle, and keeps them until
//!   an explicit [`Fleet::shutdown`];
//! * a graph execution is a [`SessionHandle`] returned by
//!   [`Fleet::submit`] — per-session [`AtomicDepTracker`], per-session
//!   quiescence (the completion that drains the session's remaining-op
//!   count raises its done flag), per-session trace and steal/dispatch
//!   counters. Many sessions run concurrently on one fleet;
//!   `ThreadedGraphi::run` is now just submit-one-session-and-wait.
//!
//! # Session-id packing
//!
//! Work-stealing deque entries must say *which graph* a node id belongs to
//! once sessions interleave. Entries are re-packed as
//! `[quantized CP level : 32 | session slot : 8 | node : 24]`
//! ([`crate::engine::ready::pack_session_entry`]): the level field is
//! unchanged from the single-graph packing, so every PR-3/PR-4 property of
//! [`crate::engine::worksteal`] carries over verbatim — owner LIFO pops
//! stay batch-hottest-first, `steal_highest`/`steal_highest_numa` still
//! rank victims by one integer compare, and `entry_level` still feeds the
//! NUMA cross-margin rule. Slots are reused: at most
//! [`FleetConfig::max_sessions`] (≤ 256) sessions are in flight, and a
//! slot is recycled only after its session's final op completes — at which
//! point no deque can still hold one of its entries (every entry is popped
//! before the op it names executes, and quiescence requires every op).
//!
//! # CP-first across sessions (the approximation)
//!
//! Within one session the §4.3 guarantee is exactly PR-3's: level
//! monotonicity along dependency chains plus ascending batch pushes keep
//! the owner's LIFO end and the thieves' ranked steal end on the hottest
//! work. *Across* sessions, packed keys compare raw quantized levels, so
//! "CP-first" means "deepest remaining critical path anywhere on the
//! fleet wins" — a session near its sink (small levels) yields to a
//! freshly admitted session (large levels). That is global
//! shortest-remaining-path-first, the approximation this module chooses
//! deliberately: it drains stragglers' tails only when no deeper work
//! exists, which minimizes the number of sessions whose critical path
//! starves. Exact per-session fairness would need a shared priority
//! structure — the serialized coordinator decentralized dispatch exists to
//! remove. The differential suite (`tests/serve_sessions.rs`) pins the
//! semantics: per-session exactly-once and dependency order, solo runs and
//! concurrent runs producing the same per-session op sets.
//!
//! New sessions are seeded through a fleet-wide **injector** (a mutexed
//! max-heap of packed keys): submitters are not deque owners, so they may
//! not push into executor deques. Executors drain the injector after their
//! own deque (and their overflow spill) and before stealing; the eventcount
//! protocol covers it, so a submit either lands before an idle executor's
//! registered re-scan or wakes a parked one.
//!
//! # Admission ([`SessionQueue`])
//!
//! §5.1's memory planner ([`crate::graph::memory::plan`]) finally meets
//! the runtime: a [`SessionQueue`] holds a byte budget (16 GB MCDRAM by
//! default in `graphi serve`) and [`SessionQueue::admit`] blocks a client
//! until its session's planned peak arena footprint fits alongside the
//! sessions already in flight. A session whose own footprint exceeds the
//! whole budget is admitted only alone — the queue degrades to serial
//! execution rather than deadlocking or lying about memory.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{Scope, ScopedJoinHandle};
use std::time::{Duration, Instant};

use crate::engine::backoff::{Backoff, BackoffStage, EventCounter};
use crate::engine::mpsc::MpscQueue;
use crate::engine::ready::{
    pack_session_entry, session_entry_node, session_entry_slot, SESSION_NODE_BITS,
};
use crate::engine::ring::SpscRing;
use crate::engine::scheduler::IdleBitmap;
use crate::engine::trace::OpRecord;
use crate::engine::worksteal::{self, Acquire, DomainMap, WorkStealDeque};
use crate::engine::DispatchMode;
use crate::graph::{AtomicDepTracker, Graph, NodeId};

/// How long a parked thread sleeps before re-checking the world anyway —
/// purely a backstop; producers wake parked threads through the
/// eventcount (see [`crate::engine::backoff`]).
const PARK_TIMEOUT: Duration = Duration::from_millis(1);

/// Hard cap on in-flight sessions: the packed key's slot field is 8 bits.
pub const MAX_SESSIONS: usize = 256;

/// Hard cap on a session graph's node count: the packed key's node field.
pub const MAX_SESSION_NODES: usize = 1 << SESSION_NODE_BITS;

/// Shape and policy of a persistent fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Executor threads, spawned once at [`Fleet::new`].
    pub executors: usize,
    /// Completion-resolution architecture. Decentralized executors resolve
    /// successors themselves; centralized mode spawns one extra scheduler
    /// thread that owns every dispatch decision (the §4/§5 design).
    pub dispatch: DispatchMode,
    /// Per-executor operation buffer depth (centralized mode; §5.2 uses 1).
    pub buffer_depth: usize,
    /// Executor→NUMA-domain map for victim ranking in decentralized mode;
    /// `None` = flat (domain-blind).
    pub numa: Option<DomainMap>,
    /// Session slots (bound on concurrently in-flight sessions, ≤
    /// [`MAX_SESSIONS`]). [`Fleet::submit`] blocks when all are taken.
    pub max_sessions: usize,
    /// Per-executor deque capacity (decentralized mode). Overflow falls
    /// back to an owner-local spill vector — correct, just not stealable —
    /// so this is a performance knob, not a correctness bound.
    pub deque_capacity: usize,
}

impl FleetConfig {
    pub fn new(executors: usize) -> FleetConfig {
        FleetConfig {
            executors,
            dispatch: DispatchMode::Decentralized,
            buffer_depth: 1,
            numa: None,
            max_sessions: 32,
            deque_capacity: 1 << 15,
        }
    }

    pub fn with_dispatch(mut self, dispatch: DispatchMode) -> FleetConfig {
        self.dispatch = dispatch;
        self
    }
}

/// Fleet-lifetime totals (monotone counters over all sessions).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetTotals {
    /// Ops handed to an executor (local pop / steal / ring push).
    pub dispatches: u64,
    /// Ops acquired by stealing (decentralized mode).
    pub steals: u64,
    /// Of `steals`, how many crossed a NUMA-domain boundary.
    pub cross_domain_steals: u64,
    /// Times an idle fleet thread actually slept on the eventcount.
    /// Parks are a property of the *fleet* (an executor parks because no
    /// session anywhere has work for it), so they are not attributed to
    /// individual sessions.
    pub parks: u64,
    /// Sessions that ran to quiescence.
    pub sessions_completed: u64,
    /// Executor threads that ever started on this fleet — spawned once at
    /// construction, so this never grows with submissions (the acceptance
    /// test reads it from the post-join snapshot [`Fleet::shutdown`]
    /// returns, where every started thread is guaranteed counted).
    pub executor_threads: u64,
}

#[derive(Debug, Default)]
struct Counters {
    dispatches: AtomicU64,
    steals: AtomicU64,
    cross_domain_steals: AtomicU64,
    parks: AtomicU64,
    sessions_completed: AtomicU64,
    /// Executor threads that ever started on this fleet — the
    /// spawned-once proof the acceptance test reads.
    executor_threads: AtomicUsize,
}

/// One in-flight (or just-finished) graph execution.
///
/// Owned behind an `Arc` by the submitting client and by any executor
/// whose slot cache still references it; all runtime state is per-session
/// so two sessions never contend on anything but the deques themselves.
struct SessionState<'env> {
    slot: u8,
    graph: &'env Graph,
    levels: Arc<[f64]>,
    work: &'env (dyn Fn(NodeId) + Send + Sync),
    deps: AtomicDepTracker,
    /// Session epoch: records and the wall clock are relative to submit.
    t0: Instant,
    /// Per-executor record buckets (each executor locks only its own).
    records: Vec<Mutex<Vec<OpRecord>>>,
    dispatches: AtomicU64,
    steals: AtomicU64,
    cross_domain_steals: AtomicU64,
    /// `Some(wall_us)` once the final op completed; guarded by `done_cv`.
    done: Mutex<Option<f64>>,
    done_cv: Condvar,
}

/// One session slot of the registry: a monotone install sequence number
/// (for executor-local caching) plus the installed session.
struct SlotCell<'env> {
    seq: AtomicU64,
    state: Mutex<Option<Arc<SessionState<'env>>>>,
}

/// Everything the fleet threads share.
struct FleetShared<'env> {
    executors: usize,
    buffer_depth: usize,
    domains: DomainMap,
    // decentralized: per-executor deques + the submission injector
    deques: Vec<WorkStealDeque>,
    injector: Mutex<BinaryHeap<u64>>,
    /// Racy emptiness hint so idle sweeps skip the injector lock.
    injector_len: AtomicUsize,
    // centralized: scheduler-owned rings + the shared completion queue
    rings: Vec<SpscRing<u64>>,
    done_q: MpscQueue<(u32, u64)>,
    installs: Mutex<Vec<Arc<SessionState<'env>>>>,
    installs_pending: AtomicBool,
    /// Wakes the centralized scheduler (completions, installs, shutdown).
    sched_events: EventCounter,
    /// Wakes executors (new deque/injector/ring work, shutdown).
    events: EventCounter,
    shutdown: AtomicBool,
    slots: Vec<SlotCell<'env>>,
    free_slots: Mutex<Vec<u8>>,
    slot_available: Condvar,
    next_seq: AtomicU64,
    active_sessions: AtomicUsize,
    counters: Counters,
}

impl<'env> FleetShared<'env> {
    fn new(config: &FleetConfig) -> FleetShared<'env> {
        let n = config.executors;
        FleetShared {
            executors: n,
            buffer_depth: config.buffer_depth,
            domains: config.numa.clone().unwrap_or_else(|| DomainMap::flat(n)),
            deques: (0..n).map(|_| WorkStealDeque::new(config.deque_capacity)).collect(),
            injector: Mutex::new(BinaryHeap::new()),
            injector_len: AtomicUsize::new(0),
            rings: (0..n).map(|_| SpscRing::new(config.buffer_depth)).collect(),
            // bound on un-drained completions: each executor holds at most
            // `buffer_depth` ops it could have finished before the
            // scheduler drains (push degrades to a bounded retry anyway)
            done_q: MpscQueue::new(n * config.buffer_depth + n + 8),
            installs: Mutex::new(Vec::new()),
            installs_pending: AtomicBool::new(false),
            sched_events: EventCounter::new(),
            events: EventCounter::new(),
            shutdown: AtomicBool::new(false),
            slots: (0..config.max_sessions)
                .map(|_| SlotCell { seq: AtomicU64::new(0), state: Mutex::new(None) })
                .collect(),
            // pop from the end ⇒ low slots are handed out first
            free_slots: Mutex::new((0..config.max_sessions).rev().map(|s| s as u8).collect()),
            slot_available: Condvar::new(),
            next_seq: AtomicU64::new(0),
            active_sessions: AtomicUsize::new(0),
            counters: Counters::default(),
        }
    }

    fn totals_snapshot(&self) -> FleetTotals {
        FleetTotals {
            dispatches: self.counters.dispatches.load(Ordering::SeqCst),
            steals: self.counters.steals.load(Ordering::SeqCst),
            cross_domain_steals: self.counters.cross_domain_steals.load(Ordering::SeqCst),
            parks: self.counters.parks.load(Ordering::SeqCst),
            sessions_completed: self.counters.sessions_completed.load(Ordering::SeqCst),
            executor_threads: self.counters.executor_threads.load(Ordering::SeqCst) as u64,
        }
    }
}

/// Resolve a packed key's slot to its live session, through an
/// executor-local cache keyed by the slot's install sequence number.
///
/// Why this is race-free: an entry for slot `s` can only exist between
/// the session's install and its final completion (every entry is popped
/// before its op runs, and quiescence needs every op), so whatever the
/// slot currently holds *is* the entry's session; the cache only avoids
/// re-locking while the sequence number is unchanged.
fn lookup<'env>(
    shared: &FleetShared<'env>,
    cache: &mut [Option<(u64, Arc<SessionState<'env>>)>],
    slot: u8,
) -> Arc<SessionState<'env>> {
    let cell = &shared.slots[slot as usize];
    let seq = cell.seq.load(Ordering::Acquire);
    if let Some((cached_seq, state)) = &cache[slot as usize] {
        if *cached_seq == seq {
            return Arc::clone(state);
        }
    }
    let state = cell
        .state
        .lock()
        .unwrap()
        .clone()
        .expect("live entry for a session that is not installed");
    cache[slot as usize] = Some((seq, Arc::clone(&state)));
    state
}

/// Final-completion bookkeeping: release the slot, flip the session's
/// done flag, and wake everyone who might care (waiters, submitters
/// blocked on a slot, parked fleet threads, the scheduler).
fn finish_session<'env>(shared: &FleetShared<'env>, session: &Arc<SessionState<'env>>) {
    let wall_us = session.t0.elapsed().as_secs_f64() * 1e6;
    *shared.slots[session.slot as usize].state.lock().unwrap() = None;
    shared.free_slots.lock().unwrap().push(session.slot);
    shared.slot_available.notify_all();
    shared.active_sessions.fetch_sub(1, Ordering::SeqCst);
    shared.counters.sessions_completed.fetch_add(1, Ordering::Relaxed);
    *session.done.lock().unwrap() = Some(wall_us);
    session.done_cv.notify_all();
    shared.events.notify();
    shared.sched_events.notify();
}

/// Decentralized acquisition sweep for executor `e`: own deque's LIFO end,
/// then the owner-local spill (deque-overflow fallback), then the
/// session injector, then the NUMA-ranked highest-priority steal.
fn acquire(shared: &FleetShared<'_>, e: usize, spill: &mut Vec<u64>) -> Option<(u64, Acquire)> {
    if let Some(key) = shared.deques[e].pop() {
        return Some((key, Acquire::LocalPop));
    }
    if let Some(key) = spill.pop() {
        return Some((key, Acquire::LocalPop));
    }
    if shared.injector_len.load(Ordering::Acquire) > 0 {
        let mut inj = shared.injector.lock().unwrap();
        let got = inj.pop();
        shared.injector_len.store(inj.len(), Ordering::Release);
        drop(inj);
        if let Some(key) = got {
            return Some((key, Acquire::LocalPop));
        }
    }
    worksteal::steal_highest_numa(&shared.deques, e, &shared.domains)
}

/// Decentralized executor body: PR-3's executor-side successor resolution,
/// now multi-session (the key's slot routes every touch to the right
/// session's tracker, records, and counters).
fn executor_decentralized<'env>(shared: &FleetShared<'env>, e: usize) {
    let mut cache: Vec<Option<(u64, Arc<SessionState<'env>>)>> =
        (0..shared.slots.len()).map(|_| None).collect();
    let mut spill: Vec<u64> = Vec::new();
    let mut batch: Vec<u64> = Vec::new();
    let mut backoff = Backoff::new();
    loop {
        // park-stage registration before the sweep — the eventcount's
        // lost-wakeup guard (see crate::engine::backoff)
        let prepared = (backoff.stage() == BackoffStage::Park).then(|| shared.events.prepare());
        match acquire(shared, e, &mut spill) {
            Some((key, kind)) => {
                if prepared.is_some() {
                    shared.events.cancel();
                }
                backoff.reset();
                let slot = session_entry_slot(key);
                let node = session_entry_node(key);
                let session = lookup(shared, &mut cache, slot);
                shared.counters.dispatches.fetch_add(1, Ordering::Relaxed);
                session.dispatches.fetch_add(1, Ordering::Relaxed);
                if kind.is_steal() {
                    shared.counters.steals.fetch_add(1, Ordering::Relaxed);
                    session.steals.fetch_add(1, Ordering::Relaxed);
                    if kind == Acquire::StealCrossDomain {
                        shared.counters.cross_domain_steals.fetch_add(1, Ordering::Relaxed);
                        session.cross_domain_steals.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let start = session.t0.elapsed().as_secs_f64() * 1e6;
                (session.work)(node);
                let end = session.t0.elapsed().as_secs_f64() * 1e6;
                session.records[e]
                    .lock()
                    .unwrap()
                    .push(OpRecord { node, executor: e as u32, start_us: start, end_us: end });
                // resolve successors against the *session's* tracker and
                // push them onto the own deque, ascending so the LIFO end
                // is the batch's highest-level op
                batch.clear();
                {
                    let levels = &session.levels;
                    let last = session.deps.complete(session.graph, node, |s| {
                        batch.push(pack_session_entry(levels[s as usize], slot, s));
                    });
                    batch.sort_unstable();
                    let mut spilled = false;
                    for &k in &batch {
                        if shared.deques[e].push(k).is_err() {
                            spill.push(k);
                            spilled = true;
                        }
                    }
                    if spilled {
                        spill.sort_unstable();
                    }
                    if !batch.is_empty() {
                        shared.events.notify();
                    }
                    if last {
                        finish_session(shared, &session);
                        cache[slot as usize] = None;
                    }
                }
            }
            None => {
                if shared.shutdown.load(Ordering::Acquire) {
                    if prepared.is_some() {
                        shared.events.cancel();
                    }
                    return;
                }
                match backoff.next() {
                    BackoffStage::Spin => std::hint::spin_loop(),
                    BackoffStage::Yield => std::thread::yield_now(),
                    BackoffStage::Park => {
                        // about to sleep: drop cached session Arcs so a
                        // finished session's O(nodes) tracker/levels are
                        // not pinned across an idle period (the cache
                        // rebuilds with one registry lock per slot on the
                        // next burst)
                        cache.iter_mut().for_each(|c| *c = None);
                        let observed = prepared.expect("park stage registers before the sweep");
                        if shared.events.park(observed, PARK_TIMEOUT) {
                            shared.counters.parks.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }
    }
}

/// Centralized executor body (Algorithm 2): poll the own ring, execute,
/// report the completion back to the scheduler thread.
fn executor_centralized<'env>(shared: &FleetShared<'env>, e: usize) {
    let mut cache: Vec<Option<(u64, Arc<SessionState<'env>>)>> =
        (0..shared.slots.len()).map(|_| None).collect();
    let mut backoff = Backoff::new();
    loop {
        let prepared = (backoff.stage() == BackoffStage::Park).then(|| shared.events.prepare());
        if let Some(key) = shared.rings[e].pop() {
            if prepared.is_some() {
                shared.events.cancel();
            }
            backoff.reset();
            let slot = session_entry_slot(key);
            let node = session_entry_node(key);
            let session = lookup(shared, &mut cache, slot);
            let start = session.t0.elapsed().as_secs_f64() * 1e6;
            (session.work)(node);
            let end = session.t0.elapsed().as_secs_f64() * 1e6;
            session.records[e]
                .lock()
                .unwrap()
                .push(OpRecord { node, executor: e as u32, start_us: start, end_us: end });
            // the queue is sized for every in-flight op; degrade to a
            // bounded retry rather than ever losing a completion
            let mut item = (e as u32, key);
            while let Err(back) = shared.done_q.push(item) {
                item = back;
                std::thread::yield_now();
            }
            shared.sched_events.notify();
        } else if shared.shutdown.load(Ordering::Acquire) {
            if prepared.is_some() {
                shared.events.cancel();
            }
            return;
        } else {
            match backoff.next() {
                BackoffStage::Spin => std::hint::spin_loop(),
                BackoffStage::Yield => std::thread::yield_now(),
                BackoffStage::Park => {
                    // idle: drop cached session Arcs (see the
                    // decentralized loop for the rationale)
                    cache.iter_mut().for_each(|c| *c = None);
                    let observed = prepared.expect("park stage registers before polling");
                    if shared.events.park(observed, PARK_TIMEOUT) {
                        shared.counters.parks.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
}

/// Centralized scheduler body (Algorithm 1), multi-session: one max-heap
/// of packed keys orders ready ops CP-first *across* sessions, installs
/// seed new sessions' sources, completions resolve against the owning
/// session's tracker.
fn scheduler_loop<'env>(shared: &FleetShared<'env>) {
    let n_exec = shared.executors;
    let depth = shared.buffer_depth;
    let mut ready: BinaryHeap<u64> = BinaryHeap::new();
    let mut cache: Vec<Option<(u64, Arc<SessionState<'env>>)>> =
        (0..shared.slots.len()).map(|_| None).collect();
    let mut inflight = vec![0usize; n_exec];
    let mut available = IdleBitmap::new(n_exec);
    let mut completions: Vec<(u32, u64)> = Vec::with_capacity(n_exec * 2 + 8);
    let mut backoff = Backoff::new();
    loop {
        let prepared =
            (backoff.stage() == BackoffStage::Park).then(|| shared.sched_events.prepare());
        let mut progressed = false;
        // newly submitted sessions: seed their sources into the heap
        if shared.installs_pending.swap(false, Ordering::AcqRel) {
            let pending: Vec<Arc<SessionState<'env>>> = {
                let mut q = shared.installs.lock().unwrap();
                q.drain(..).collect()
            };
            for session in &pending {
                for s in session.graph.sources() {
                    ready.push(pack_session_entry(session.levels[s as usize], session.slot, s));
                }
                progressed = true;
            }
        }
        // drain the shared completion queue in one batch
        completions.clear();
        shared.done_q.pop_batch(&mut completions, usize::MAX);
        for &(e, key) in completions.iter() {
            let e = e as usize;
            inflight[e] -= 1;
            if inflight[e] == depth - 1 && !available.is_idle(e) {
                available.set_idle(e);
            }
            let slot = session_entry_slot(key);
            let node = session_entry_node(key);
            let session = lookup(shared, &mut cache, slot);
            let last = {
                let levels = &session.levels;
                session.deps.complete(session.graph, node, |s| {
                    ready.push(pack_session_entry(levels[s as usize], slot, s));
                })
            };
            if last {
                finish_session(shared, &session);
                cache[slot as usize] = None;
            }
            progressed = true;
        }
        // dispatch: max-key ops → first available executor (bit-scan)
        let mut pushed_any = false;
        while !ready.is_empty() && available.any_idle() {
            let e = available.first_idle().expect("any_idle checked");
            while inflight[e] < depth {
                let Some(key) = ready.pop() else { break };
                shared.rings[e].push(key).expect("availability bit ⇒ ring space");
                inflight[e] += 1;
                pushed_any = true;
                shared.counters.dispatches.fetch_add(1, Ordering::Relaxed);
                let session = lookup(shared, &mut cache, session_entry_slot(key));
                session.dispatches.fetch_add(1, Ordering::Relaxed);
            }
            if inflight[e] >= depth {
                available.set_busy(e);
            } else {
                break; // heap drained with buffer room to spare
            }
        }
        if pushed_any {
            shared.events.notify();
            progressed = true;
        }
        if progressed {
            if prepared.is_some() {
                shared.sched_events.cancel();
            }
            backoff.reset();
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            if prepared.is_some() {
                shared.sched_events.cancel();
            }
            // shutdown is contractually called only after every session
            // quiesced; if that contract is broken (handle dropped
            // without wait, panic unwinding a fleet), exit anyway —
            // abandoning the sessions loudly beats deadlocking the
            // join in `Fleet::halt` (executors are exiting too, so no
            // completion could ever drain the remaining ops)
            let abandoned = shared.active_sessions.load(Ordering::SeqCst);
            if abandoned > 0 {
                crate::log_warn!(
                    "fleet scheduler stopping with {abandoned} session(s) still in flight \
                     (shutdown before wait?)"
                );
            }
            return;
        }
        match backoff.next() {
            BackoffStage::Spin => std::hint::spin_loop(),
            BackoffStage::Yield => std::thread::yield_now(),
            BackoffStage::Park => {
                let observed = prepared.expect("park stage registers before polling");
                if shared.sched_events.park(observed, PARK_TIMEOUT) {
                    shared.counters.parks.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

/// A long-lived executor fleet: threads spawned once, sessions submitted
/// many times. Scoped to a [`std::thread::Scope`] so sessions may borrow
/// anything that outlives the scope (graphs, work closures) with zero
/// `unsafe` — the pattern `ThreadedGraphi::run` and `graphi serve` both
/// build on.
pub struct Fleet<'scope, 'env> {
    shared: Arc<FleetShared<'env>>,
    handles: Vec<ScopedJoinHandle<'scope, ()>>,
    config: FleetConfig,
}

impl<'scope, 'env> Fleet<'scope, 'env> {
    /// Spawn the fleet's threads (executors, plus one scheduler thread in
    /// centralized mode). This is the only place threads are created.
    pub fn new(scope: &'scope Scope<'scope, 'env>, config: FleetConfig) -> Fleet<'scope, 'env> {
        assert!(config.executors >= 1, "a fleet needs at least one executor");
        assert!(config.buffer_depth >= 1, "buffer depth must be at least 1");
        assert!(
            (1..=MAX_SESSIONS).contains(&config.max_sessions),
            "max_sessions must be in 1..={MAX_SESSIONS} (8-bit slot field)"
        );
        if let Some(map) = &config.numa {
            assert_eq!(map.len(), config.executors, "one domain per executor");
        }
        let shared = Arc::new(FleetShared::new(&config));
        let mut handles = Vec::with_capacity(config.executors + 1);
        for e in 0..config.executors {
            let sh = Arc::clone(&shared);
            let dispatch = config.dispatch;
            handles.push(scope.spawn(move || {
                sh.counters.executor_threads.fetch_add(1, Ordering::SeqCst);
                match dispatch {
                    DispatchMode::Decentralized => executor_decentralized(&sh, e),
                    DispatchMode::Centralized => executor_centralized(&sh, e),
                }
            }));
        }
        if config.dispatch == DispatchMode::Centralized {
            let sh = Arc::clone(&shared);
            handles.push(scope.spawn(move || scheduler_loop(&sh)));
        }
        Fleet { shared, handles, config }
    }

    pub fn executors(&self) -> usize {
        self.config.executors
    }

    pub fn dispatch(&self) -> DispatchMode {
        self.config.dispatch
    }

    /// Executor threads that have ever started on this fleet. Spawned
    /// once at construction: submitting more sessions never grows it.
    pub fn executor_threads_started(&self) -> usize {
        self.shared.counters.executor_threads.load(Ordering::SeqCst)
    }

    /// Sessions currently submitted but not yet quiesced.
    pub fn active_sessions(&self) -> usize {
        self.shared.active_sessions.load(Ordering::SeqCst)
    }

    /// Fleet-lifetime counter snapshot.
    pub fn totals(&self) -> FleetTotals {
        self.shared.totals_snapshot()
    }

    /// Submit a graph execution. Blocks only if every session slot is
    /// taken (bound memory with a [`SessionQueue`] *before* submitting).
    /// `work(node)` runs on some executor thread for each op,
    /// dependencies respected; `levels` orders ops CP-first within and
    /// across sessions (see the module docs).
    pub fn submit(
        &self,
        graph: &'env Graph,
        levels: impl Into<Arc<[f64]>>,
        work: &'env (dyn Fn(NodeId) + Send + Sync),
    ) -> SessionHandle<'env> {
        let levels: Arc<[f64]> = levels.into();
        assert_eq!(levels.len(), graph.len(), "one level per node");
        assert!(
            graph.len() < MAX_SESSION_NODES,
            "session graphs are limited to {MAX_SESSION_NODES} nodes by the packed key's node field"
        );
        let shared = &self.shared;
        let slot = {
            let mut free = shared.free_slots.lock().unwrap();
            loop {
                if let Some(s) = free.pop() {
                    break s;
                }
                free = shared.slot_available.wait(free).unwrap();
            }
        };
        let seq = shared.next_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let state = Arc::new(SessionState {
            slot,
            graph,
            levels,
            work,
            deps: AtomicDepTracker::new(graph),
            t0: Instant::now(),
            records: (0..self.config.executors).map(|_| Mutex::new(Vec::new())).collect(),
            dispatches: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            cross_domain_steals: AtomicU64::new(0),
            done: Mutex::new(None),
            done_cv: Condvar::new(),
        });
        shared.active_sessions.fetch_add(1, Ordering::SeqCst);
        *shared.slots[slot as usize].state.lock().unwrap() = Some(Arc::clone(&state));
        shared.slots[slot as usize].seq.store(seq, Ordering::Release);
        match self.config.dispatch {
            DispatchMode::Decentralized => {
                // submitters are not deque owners — seed through the
                // injector, which executors drain before stealing
                {
                    let mut inj = shared.injector.lock().unwrap();
                    for s in graph.sources() {
                        inj.push(pack_session_entry(state.levels[s as usize], slot, s));
                    }
                    shared.injector_len.store(inj.len(), Ordering::Release);
                }
                shared.events.notify();
            }
            DispatchMode::Centralized => {
                shared.installs.lock().unwrap().push(Arc::clone(&state));
                shared.installs_pending.store(true, Ordering::Release);
                shared.sched_events.notify();
            }
        }
        SessionHandle { state }
    }

    fn halt(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        debug_assert_eq!(
            self.shared.active_sessions.load(Ordering::SeqCst),
            0,
            "fleet shutdown with sessions still in flight"
        );
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.events.notify();
        self.shared.sched_events.notify();
        for h in self.handles.drain(..) {
            h.join().expect("fleet thread panicked");
        }
    }

    /// Stop and join every fleet thread (all sessions must have completed
    /// first); returns the final counter snapshot. A clean shutdown *is*
    /// the no-leaked-threads proof: every handle is joined here. Calling
    /// it with sessions still in flight is a contract violation: the
    /// fleet still exits (threads abandon the remaining ops with a
    /// warning rather than deadlocking the join), but those sessions
    /// never quiesce and their waiters would block forever.
    pub fn shutdown(mut self) -> FleetTotals {
        self.halt();
        self.shared.totals_snapshot()
    }
}

impl Drop for Fleet<'_, '_> {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Handle to one submitted session.
pub struct SessionHandle<'env> {
    state: Arc<SessionState<'env>>,
}

/// What a finished session reports back.
#[derive(Debug)]
pub struct SessionReport {
    /// Submit-to-quiescence wall time, µs.
    pub wall_us: f64,
    /// Per-op records (µs since submit), sorted by start time.
    pub records: Vec<OpRecord>,
    /// Ops dispatched for this session (= its node count).
    pub dispatches: u64,
    /// Of those, acquired by stealing (decentralized fleets).
    pub steals: u64,
    /// Of the steals, cross-NUMA-domain ones.
    pub cross_domain_steals: u64,
}

impl<'env> SessionHandle<'env> {
    /// Has the session's final op completed? (Non-blocking.)
    pub fn is_done(&self) -> bool {
        self.state.done.lock().unwrap().is_some()
    }

    /// Block until the session quiesces, then merge its trace and
    /// counters. The final completion's release sequence orders every
    /// executor's record writes before the done flag, so the merge is
    /// complete by construction.
    pub fn wait(self) -> SessionReport {
        let wall_us = {
            let mut done = self.state.done.lock().unwrap();
            loop {
                if let Some(w) = *done {
                    break w;
                }
                done = self.state.done_cv.wait(done).unwrap();
            }
        };
        let mut records: Vec<OpRecord> = Vec::with_capacity(self.state.graph.len());
        for bucket in self.state.records.iter() {
            records.extend(bucket.lock().unwrap().drain(..));
        }
        records.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        SessionReport {
            wall_us,
            records,
            dispatches: self.state.dispatches.load(Ordering::SeqCst),
            steals: self.state.steals.load(Ordering::SeqCst),
            cross_domain_steals: self.state.cross_domain_steals.load(Ordering::SeqCst),
        }
    }
}

/// §5.1 admission control: a byte budget over the *planned peak arena
/// footprints* of in-flight sessions ([`crate::graph::memory::plan`]).
/// [`admit`](SessionQueue::admit) blocks until the session fits; a session
/// larger than the whole budget is admitted only when nothing else is in
/// flight (serial degradation instead of deadlock).
///
/// Admission is **FIFO-ticketed**: blocked requests are served strictly in
/// arrival order, so a large-footprint session cannot be starved by a
/// sustained stream of smaller sessions slipping into each freed gap —
/// the head-of-line request always gets the next shot at the budget (the
/// price is that requests behind a blocked head wait with it, the usual
/// fairness/throughput trade; [`try_admit`](SessionQueue::try_admit)
/// refuses to jump an existing queue).
#[derive(Debug)]
pub struct SessionQueue {
    budget_bytes: u64,
    state: Mutex<QueueState>,
    cv: Condvar,
}

#[derive(Debug, Default)]
struct QueueState {
    in_use: u64,
    /// Next ticket to hand out to a blocking `admit`.
    next_ticket: u64,
    /// Ticket currently at the head of the line (== `next_ticket` when
    /// nobody is waiting).
    head: u64,
}

impl SessionQueue {
    pub fn new(budget_bytes: u64) -> SessionQueue {
        SessionQueue { budget_bytes, state: Mutex::new(QueueState::default()), cv: Condvar::new() }
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Bytes currently admitted.
    pub fn in_use(&self) -> u64 {
        self.state.lock().unwrap().in_use
    }

    /// Requests currently blocked in [`admit`](Self::admit).
    pub fn waiting(&self) -> u64 {
        let state = self.state.lock().unwrap();
        state.next_ticket - state.head
    }

    fn fits(&self, used: u64, bytes: u64) -> bool {
        used == 0 || used.saturating_add(bytes) <= self.budget_bytes
    }

    /// Block until `bytes` fit under the budget (FIFO among blocked
    /// requests); the permit returns the bytes on drop.
    pub fn admit(&self, bytes: u64) -> AdmissionPermit<'_> {
        let mut state = self.state.lock().unwrap();
        let ticket = state.next_ticket;
        state.next_ticket += 1;
        while !(state.head == ticket && self.fits(state.in_use, bytes)) {
            state = self.cv.wait(state).unwrap();
        }
        state.head += 1;
        state.in_use += bytes;
        drop(state);
        // the next ticket holder may already fit — let it re-check
        self.cv.notify_all();
        AdmissionPermit { queue: self, bytes }
    }

    /// Non-blocking [`admit`](Self::admit): succeeds only when the bytes
    /// fit *and* no earlier request is queued (no queue jumping).
    pub fn try_admit(&self, bytes: u64) -> Option<AdmissionPermit<'_>> {
        let mut state = self.state.lock().unwrap();
        if state.head == state.next_ticket && self.fits(state.in_use, bytes) {
            state.in_use += bytes;
            Some(AdmissionPermit { queue: self, bytes })
        } else {
            None
        }
    }
}

/// An admitted session's claim on the memory budget; released on drop.
#[derive(Debug)]
pub struct AdmissionPermit<'a> {
    queue: &'a SessionQueue,
    bytes: u64,
}

impl AdmissionPermit<'_> {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        let mut state = self.queue.state.lock().unwrap();
        state.in_use -= self.bytes;
        drop(state);
        self.queue.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build as mlp, MlpConfig};
    use std::sync::atomic::AtomicU32;

    fn unit_levels(g: &Graph) -> Vec<f64> {
        vec![1.0; g.len()]
    }

    #[test]
    fn one_session_runs_to_quiescence_in_both_modes() {
        let g = mlp(&MlpConfig::default());
        for mode in DispatchMode::ALL {
            let counts: Vec<AtomicU32> = (0..g.len()).map(|_| AtomicU32::new(0)).collect();
            let work = |n: NodeId| {
                counts[n as usize].fetch_add(1, Ordering::SeqCst);
            };
            let totals = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
                let report = fleet.submit(&g, unit_levels(&g), &work).wait();
                assert_eq!(report.records.len(), g.len(), "{}", mode.name());
                assert_eq!(report.dispatches, g.len() as u64, "{}", mode.name());
                fleet.shutdown()
            });
            for (v, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::SeqCst), 1, "{}: node {v}", mode.name());
            }
            assert_eq!(totals.dispatches, g.len() as u64, "{}", mode.name());
            assert_eq!(totals.sessions_completed, 1, "{}", mode.name());
        }
    }

    #[test]
    fn tiny_deques_spill_without_losing_ops() {
        // a 1 → 32 → 1 fan through capacity-2 deques: nearly every
        // successor push overflows into the owner-local spill, and the
        // session must still run every op exactly once
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let src = b.add("src", OpKind::Scalar);
        let mids: Vec<NodeId> = (0..32)
            .map(|i| {
                let m = b.add(format!("m{i}"), OpKind::Scalar);
                b.depend(src, m);
                m
            })
            .collect();
        b.add_after("sink", OpKind::Scalar, &mids);
        let g = b.build().unwrap();
        let counts: Vec<AtomicU32> = (0..g.len()).map(|_| AtomicU32::new(0)).collect();
        let work = |n: NodeId| {
            counts[n as usize].fetch_add(1, Ordering::SeqCst);
        };
        std::thread::scope(|scope| {
            let config = FleetConfig { deque_capacity: 2, ..FleetConfig::new(4) };
            let fleet = Fleet::new(scope, config);
            let report = fleet.submit(&g, unit_levels(&g), &work).wait();
            assert_eq!(report.records.len(), g.len());
            fleet.shutdown();
        });
        for c in &counts {
            assert_eq!(c.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn session_queue_blocks_until_budget_frees() {
        let q = SessionQueue::new(1000);
        let a = q.admit(800);
        assert_eq!(q.in_use(), 800);
        assert!(q.try_admit(300).is_none(), "over budget must not admit");
        let b = q.try_admit(200).expect("fits alongside");
        drop(b);
        std::thread::scope(|s| {
            let (tx, rx) = std::sync::mpsc::channel();
            s.spawn(|| {
                let permit = q.admit(300); // blocks until `a` drops
                tx.send(q.in_use()).unwrap();
                drop(permit);
            });
            // the admit above must still be blocked
            assert!(
                rx.recv_timeout(Duration::from_millis(100)).is_err(),
                "over-budget session must wait for the budget to free"
            );
            drop(a);
            let seen = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(seen, 300);
        });
        assert_eq!(q.in_use(), 0);
    }

    #[test]
    fn admission_is_fifo_small_sessions_cannot_starve_a_large_one() {
        let q = SessionQueue::new(100);
        let small = q.admit(60);
        std::thread::scope(|s| {
            let (tx, rx) = std::sync::mpsc::channel();
            let q = &q;
            s.spawn(move || {
                let big = q.admit(80); // blocks behind `small`
                tx.send(q.in_use()).unwrap();
                drop(big);
            });
            // wait until the large request holds the head ticket
            while q.waiting() == 0 {
                std::thread::yield_now();
            }
            // a newcomer that *would* fit must not jump the queue
            assert!(
                q.try_admit(10).is_none(),
                "try_admit jumped ahead of a queued large request"
            );
            drop(small);
            let seen = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(seen, 80, "the queued large request must be admitted next");
        });
        assert_eq!(q.in_use(), 0);
        assert_eq!(q.waiting(), 0);
    }

    #[test]
    fn oversized_session_admitted_only_alone() {
        let q = SessionQueue::new(100);
        let small = q.admit(60);
        assert!(q.try_admit(5000).is_none(), "oversized must wait while others run");
        drop(small);
        let big = q.try_admit(5000).expect("oversized runs alone");
        assert!(q.try_admit(1).is_none(), "nothing joins an oversized session");
        drop(big);
    }

    #[test]
    #[should_panic(expected = "one domain per executor")]
    fn mismatched_numa_map_rejected_at_fleet_construction() {
        std::thread::scope(|scope| {
            let config = FleetConfig {
                numa: Some(DomainMap::new(vec![0, 1], 0)),
                ..FleetConfig::new(4)
            };
            let _ = Fleet::new(scope, config);
        });
    }
}
