//! Persistent executor fleets and multi-graph serving **sessions**.
//!
//! Until PR 5 the threaded runtime spawned and joined a scoped thread
//! fleet inside every [`crate::runtime::ThreadedGraphi::run`] and executed
//! exactly one graph per fleet lifetime. That reproduces Fig. 5, but it is
//! the wrong shape for serving: Opara (arXiv:2312.10351) shows concurrent
//! inference streams are where operator-level scheduling pays off, and Liu
//! et al. (arXiv:1810.08955) show a *shared* worker pool under admission
//! control is what keeps many small concurrent graphs from strangling each
//! other. This module splits the two lifetimes apart:
//!
//! * a [`Fleet`] spawns its executor threads **once** (plus one scheduler
//!   thread in centralized mode), parks them on the
//!   [`crate::engine::backoff`] eventcount when idle, and keeps them until
//!   an explicit [`Fleet::shutdown`];
//! * a graph execution is a [`SessionHandle`] returned by
//!   [`Fleet::submit`] — per-session [`AtomicDepTracker`], per-session
//!   quiescence (the completion that drains the session's remaining-op
//!   count raises its done flag), per-session trace and steal/dispatch
//!   counters. Many sessions run concurrently on one fleet;
//!   `ThreadedGraphi::run` is now just submit-one-session-and-wait.
//!
//! # Session-id packing
//!
//! Work-stealing deque entries must say *which graph* a node id belongs to
//! once sessions interleave. Entries are re-packed as
//! `[quantized CP level : 32 | session slot : 8 | gang width − 1 : 4 | node : 20]`
//! ([`crate::engine::ready::pack_session_entry_wide`]): the level field is
//! unchanged from the single-graph packing, so every PR-3/PR-4 property of
//! [`crate::engine::worksteal`] carries over verbatim — owner LIFO pops
//! stay batch-hottest-first, `steal_highest`/`steal_highest_numa` still
//! rank victims by one integer compare, and `entry_level` still feeds the
//! NUMA cross-margin rule. A width-1 entry packs bit-identically to the
//! pre-moldable layout. Slots are reused: at most
//! [`FleetConfig::max_sessions`] (≤ 256) sessions are in flight, and a
//! slot is recycled only after its session's final op completes — at which
//! point no deque can still hold one of its entries (every entry is popped
//! before the op it names executes, and quiescence requires every op).
//!
//! # Gang formation (moldable ops)
//!
//! A [`Fleet::submit_moldable`] session carries a per-node gang width
//! `w`; popping a `w > 1` entry makes that executor the **gang leader**.
//! Leaders never push work at peers — recruitment is a bounded handshake
//! on the leader's [`GangPost`] (one post per executor in
//! [`FleetShared`]):
//!
//! 1. the leader *opens* its post (stores the popped key, bumps the
//!    post's formation epoch, flips the post state to open) and notifies
//!    the executor eventcount so parked peers wake;
//! 2. idle peers — executors whose acquisition sweep found nothing —
//!    scan the other posts before backing off and *join* an open one by
//!    CAS-incrementing the epoch-tagged join word (the epoch makes a
//!    stale CAS fail, the ABA guard across post reuses);
//! 3. after a bounded spin the leader *closes* the formation at
//!    `width = min(joined + 1, w)` — a gang **shrinks to whoever showed
//!    up** rather than ever waiting for a full house, so saturated
//!    fleets degrade to `width = 1` instead of deadlocking;
//! 4. every seated member runs `work(node, rank, width)` under its own
//!    `catch_unwind`; the leader is rank 0, writes the gang's one
//!    [`OpRecord`], resolves successors, and retires the entry. Members
//!    that joined after the close observe `rank ≥ width` and leave
//!    silently. The leader holds the post until every seated member
//!    reported done — even if the leader's own closure panicked — so a
//!    post is never reused while a member still runs against it, and the
//!    leader's un-retired entry pins the session slot for the members'
//!    registry lookups.
//!
//! A member panic poisons the session exactly like a solo op panic
//! (members call [`fail_session`] from their own thread); the fleet and
//! every other session stay healthy.
//!
//! # CP-first across sessions (the approximation)
//!
//! Within one session the §4.3 guarantee is exactly PR-3's: level
//! monotonicity along dependency chains plus ascending batch pushes keep
//! the owner's LIFO end and the thieves' ranked steal end on the hottest
//! work. *Across* sessions, packed keys compare raw quantized levels, so
//! "CP-first" means "deepest remaining critical path anywhere on the
//! fleet wins" — a session near its sink (small levels) yields to a
//! freshly admitted session (large levels). That is global
//! shortest-remaining-path-first, the approximation this module chooses
//! deliberately: it drains stragglers' tails only when no deeper work
//! exists, which minimizes the number of sessions whose critical path
//! starves. Exact per-session fairness would need a shared priority
//! structure — the serialized coordinator decentralized dispatch exists to
//! remove. The differential suite (`tests/serve_sessions.rs`) pins the
//! semantics: per-session exactly-once and dependency order, solo runs and
//! concurrent runs producing the same per-session op sets.
//!
//! New sessions are seeded through a fleet-wide **injector** (a mutexed
//! max-heap of packed keys): submitters are not deque owners, so they may
//! not push into executor deques. Executors drain the injector after their
//! own deque (and their overflow spill) and before stealing; the eventcount
//! protocol covers it, so a submit either lands before an idle executor's
//! registered re-scan or wakes a parked one.
//!
//! # Admission ([`SessionQueue`])
//!
//! §5.1's memory planner ([`crate::graph::memory::plan`]) finally meets
//! the runtime: a [`SessionQueue`] holds a byte budget (16 GB MCDRAM by
//! default in `graphi serve`) and [`SessionQueue::admit`] blocks a client
//! until its session's planned peak arena footprint fits alongside the
//! sessions already in flight. A session whose own footprint exceeds the
//! whole budget is admitted only alone — the queue degrades to serial
//! execution rather than deadlocking or lying about memory.
//!
//! The serve loop can also merge compatible waiting requests *before*
//! they reach this queue: cross-session dynamic batching
//! ([`crate::runtime::serve::Batcher`]) unions up to `--max-batch`
//! same-model requests into **one** session and one admission entry
//! (bytes are the member sum, the class/patience/deadline are the member
//! minima), so under a small-session overload the queue grants fewer,
//! larger footprints instead of thrashing the budget on tiny ones. The
//! fleet itself is batching-agnostic — a batched session is an ordinary
//! [`crate::graph::Graph::disjoint_union`] submission.
//!
//! # Failure semantics
//!
//! Each session is a **fault domain**: an op that panics, a client
//! cancellation, or a missed deadline terminates *that session only* and
//! leaves the fleet healthy for every concurrent and subsequent session.
//! The per-session state machine (transition exactly-once via a CAS on
//! the session's terminal latch):
//!
//! ```text
//!            ┌──(final op completes)──────────► Done(wall_µs)
//!            │
//! Running ───┼──(op panics, catch_unwind)─────► Failed { node, payload }
//!            │
//!            ├──(cancel() observed at pop)────► Cancelled
//!            │
//!            ├──(deadline passed at pop)──────► DeadlineExceeded
//!            │
//!            └──(watchdog: no dispatch
//!                progress while active)───────► Stalled
//! ```
//!
//! Mechanics, in the order the tentpole invariants need them:
//!
//! * **Ops run under [`std::panic::catch_unwind`]** on every executor, in
//!   both dispatch modes. A panic never unwinds an executor thread; it
//!   transitions the session to `Failed { node, payload }`.
//! * **Lazy discard.** A terminal-with-error session is *poisoned*; its
//!   entries still sitting in deques / the injector / the scheduler heap /
//!   the SPSC rings are dropped at pop time (no execution) — nothing ever
//!   walks a Chase–Lev ring to excise entries in place
//!   (see `crate::engine::worksteal`'s module docs).
//! * **Count-gated slot recycling.** Every live entry (queued *or* being
//!   processed) holds one unit of its session's live-entry count; whoever
//!   retires the count to zero releases the slot. A slot therefore cannot
//!   be recycled while any stale entry could still resolve to it — the
//!   slot-reuse ABA guard that makes the registry lookup safe even for
//!   faulted sessions whose entries outlive their terminal transition.
//! * **Waiters get a structured [`SessionError`]**, not a makespan:
//!   [`SessionHandle::wait`] returns `Result<SessionReport, SessionError>`
//!   and wakes through the same condvar as the healthy path. The memory
//!   permit is the caller's [`AdmissionPermit`] RAII guard, so a failed
//!   session releases its budget the moment the waiter drops it.
//! * **Watchdog.** An optional monitor thread ([`FleetConfig::watchdog`])
//!   detects active sessions with no dispatch progress for the configured
//!   window, emits a diagnostic dump (per-executor last entry, deque
//!   depth, park/busy state, injector backlog) and fails the stuck
//!   sessions with [`SessionError::Stalled`] so their waiters wake instead
//!   of hanging. A truly hung op still pins its executor thread — the
//!   watchdog unwedges *waiters*, it cannot kill threads.
//! * **[`Fleet::shutdown`] aggregates faults** into a [`FleetError`]
//!   (panicked fleet threads + failed-session count + final totals)
//!   rather than aborting the process on `join()`.

use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{Scope, ScopedJoinHandle};
use std::time::{Duration, Instant};

use crate::engine::backoff::{Backoff, BackoffStage, EventCounter};
use crate::engine::mpsc::MpscQueue;
use crate::engine::ready::{
    pack_session_entry_wide, session_entry_node, session_entry_slot, session_entry_width,
    MAX_WIDTH, SESSION_NODE_BITS,
};
use crate::engine::ring::SpscRing;
use crate::engine::scheduler::IdleBitmap;
use crate::engine::trace::{FleetEvent, FleetEventKind, OpRecord, FLEET_LANE};
use crate::engine::worksteal::{self, Acquire, DomainMap, WorkStealDeque};
use crate::engine::DispatchMode;
use crate::graph::{AtomicDepTracker, Graph, NodeId};

/// How long a parked thread sleeps before re-checking the world anyway —
/// purely a backstop; producers wake parked threads through the
/// eventcount (see [`crate::engine::backoff`]).
const PARK_TIMEOUT: Duration = Duration::from_millis(1);

/// Per-lane bound on recorded scheduling events: a long serve run keeps
/// its most recent telemetry in the ring instead, so the trace sink can
/// stay bounded (overflow is counted and warned about at drain time).
const EVENT_SINK_CAP: usize = 1 << 16;

/// Hard cap on in-flight sessions: the packed key's slot field is 8 bits.
pub const MAX_SESSIONS: usize = 256;

/// Hard cap on a session graph's node count: the packed key's node field.
pub const MAX_SESSION_NODES: usize = 1 << SESSION_NODE_BITS;

/// High bit of a completion tag: the executor discarded (or failed on)
/// this entry itself — the scheduler must rebalance `inflight` but must
/// neither resolve successors nor retire the entry again.
const DONE_DISCARDED: u32 = 1 << 31;

// -- gang formation (see the module docs) -----------------------------------

/// Gang-post states: no formation in progress / leader recruiting /
/// formation closed and running.
const GANG_IDLE: u32 = 0;
const GANG_OPEN: u32 = 1;
const GANG_RUNNING: u32 = 2;

/// Low bits of the epoch-tagged `joined`/`closed` words that carry a
/// member count (resp. a closed width); the rest is the formation epoch.
const GANG_COUNT_BITS: u32 = 16;
const GANG_COUNT_MASK: u64 = (1 << GANG_COUNT_BITS) - 1;

/// Bound on the leader's recruitment spin: long enough for a parked
/// peer's eventcount wake (tens of µs) to land, short enough that a
/// saturated fleet — where nobody will ever join — degrades each wide op
/// to `width = 1` after a sub-millisecond wait instead of stalling.
const GANG_SPIN: u32 = 1 << 15;

/// One executor's gang-recruitment mailbox. All transitions are described
/// in the module docs' gang-formation section; the epoch tags on `joined`
/// and `closed` are what make post reuse safe (a member acting on a stale
/// read either fails its join CAS or observes a newer epoch and leaves).
struct GangPost {
    /// `GANG_IDLE` / `GANG_OPEN` / `GANG_RUNNING`; written by the leader.
    state: AtomicU32,
    /// `[formation epoch : 48 | joined members : 16]`; members join by
    /// CAS-incrementing the count half, so a CAS against a retired
    /// formation's value fails on the epoch half.
    joined: AtomicU64,
    /// `[formation epoch : 48 | closed gang width : 16]`, written once
    /// per formation when the leader stops recruiting. A seated member
    /// spins until its own epoch appears here; a later epoch means the
    /// member joined too late for a seat.
    closed: AtomicU64,
    /// The packed session entry the gang executes. Stable while any
    /// member holds a seat: the leader's un-retired entry pins the
    /// session slot, and the post is not reused until every seated
    /// member reported `done`.
    key: AtomicU64,
    /// Seated members finished (or unwound from) their work closure.
    done: AtomicU32,
}

impl GangPost {
    fn new() -> GangPost {
        GangPost {
            state: AtomicU32::new(GANG_IDLE),
            joined: AtomicU64::new(0),
            closed: AtomicU64::new(0),
            key: AtomicU64::new(0),
            done: AtomicU32::new(0),
        }
    }
}

/// Shape and policy of a persistent fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Executor threads, spawned once at [`Fleet::new`].
    pub executors: usize,
    /// Completion-resolution architecture. Decentralized executors resolve
    /// successors themselves; centralized mode spawns one extra scheduler
    /// thread that owns every dispatch decision (the §4/§5 design).
    pub dispatch: DispatchMode,
    /// Per-executor operation buffer depth (centralized mode; §5.2 uses 1).
    pub buffer_depth: usize,
    /// Executor→NUMA-domain map for victim ranking in decentralized mode;
    /// `None` = flat (domain-blind).
    pub numa: Option<DomainMap>,
    /// Session slots (bound on concurrently in-flight sessions, ≤
    /// [`MAX_SESSIONS`]). [`Fleet::submit`] blocks when all are taken.
    pub max_sessions: usize,
    /// Per-executor deque capacity (decentralized mode). Overflow falls
    /// back to an owner-local spill vector — correct, just not stealable —
    /// so this is a performance knob, not a correctness bound.
    pub deque_capacity: usize,
    /// Spawn a watchdog thread that fails sessions making no dispatch
    /// progress for this long (see the module docs' failure-semantics
    /// section). `None` (the default) spawns no watchdog. The window must
    /// comfortably exceed the longest single op: the watchdog cannot
    /// distinguish a slow op from a hung one.
    pub watchdog: Option<Duration>,
    /// Record scheduling events (steals, parks) into per-executor sinks
    /// for the Chrome-trace exporter ([`Fleet::drain_events`]). Off by
    /// default: when disabled the sinks are not even allocated and the
    /// hot paths only test an empty-`Vec` flag.
    pub record_events: bool,
}

impl FleetConfig {
    pub fn new(executors: usize) -> FleetConfig {
        FleetConfig {
            executors,
            dispatch: DispatchMode::Decentralized,
            buffer_depth: 1,
            numa: None,
            max_sessions: 32,
            deque_capacity: 1 << 15,
            watchdog: None,
            record_events: false,
        }
    }

    pub fn with_dispatch(mut self, dispatch: DispatchMode) -> FleetConfig {
        self.dispatch = dispatch;
        self
    }

    pub fn with_watchdog(mut self, stall_after: Duration) -> FleetConfig {
        self.watchdog = Some(stall_after);
        self
    }

    pub fn with_event_recording(mut self, on: bool) -> FleetConfig {
        self.record_events = on;
        self
    }
}

/// Fleet-lifetime totals (monotone counters over all sessions).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetTotals {
    /// Ops handed to an executor (local pop / steal / ring push).
    pub dispatches: u64,
    /// Ops acquired by stealing (decentralized mode).
    pub steals: u64,
    /// Of `steals`, how many crossed a NUMA-domain boundary.
    pub cross_domain_steals: u64,
    /// Times an idle fleet thread actually slept on the eventcount.
    /// Parks are a property of the *fleet* (an executor parks because no
    /// session anywhere has work for it), so they are not attributed to
    /// individual sessions.
    pub parks: u64,
    /// Sessions that ran to quiescence.
    pub sessions_completed: u64,
    /// Sessions terminated by an op panic or the watchdog
    /// ([`SessionError::OpPanicked`] / [`SessionError::Stalled`]).
    pub sessions_failed: u64,
    /// Sessions terminated by [`SessionHandle::cancel`].
    pub sessions_cancelled: u64,
    /// Sessions terminated by a [`Fleet::submit_with_deadline`] miss.
    pub sessions_deadline_missed: u64,
    /// Requests shed at admission ([`SessionError::Shed`]): the session
    /// was never submitted, so this is the one per-outcome counter fed
    /// from outside the fleet's own state machine
    /// ([`Fleet::record_shed`]).
    pub sessions_shed: u64,
    /// Entries of poisoned sessions dropped at pop time (lazy discard).
    pub entries_discarded: u64,
    /// Moldable gangs formed: wide ops whose formation closed with an
    /// effective width > 1 (a wide op nobody joined runs solo and is not
    /// counted).
    pub gangs_formed: u64,
    /// Peer executors seated into gangs (the sum of `width − 1` over
    /// formed gangs).
    pub gang_recruits: u64,
    /// Executor threads that ever started on this fleet — spawned once at
    /// construction, so this never grows with submissions (the acceptance
    /// test reads it from the post-join snapshot [`Fleet::shutdown`]
    /// returns, where every started thread is guaranteed counted).
    pub executor_threads: u64,
}

#[derive(Debug, Default)]
struct Counters {
    dispatches: AtomicU64,
    steals: AtomicU64,
    cross_domain_steals: AtomicU64,
    parks: AtomicU64,
    sessions_completed: AtomicU64,
    sessions_failed: AtomicU64,
    sessions_cancelled: AtomicU64,
    sessions_deadline_missed: AtomicU64,
    sessions_shed: AtomicU64,
    entries_discarded: AtomicU64,
    gangs_formed: AtomicU64,
    gang_recruits: AtomicU64,
    /// Executor threads that ever started on this fleet — the
    /// spawned-once proof the acceptance test reads.
    executor_threads: AtomicUsize,
}

/// Why an admission request was rejected before its session was ever
/// submitted — the structured payload of [`SessionError::Shed`] and the
/// error half of [`SessionQueue::admit_request`]. Overload produces these
/// fast, bounded rejections instead of queueing past usefulness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The request's patience expired while it waited in line (the
    /// original deadline-bounded-wait shed path).
    AdmissionTimeout,
    /// The queue's configured depth bound
    /// ([`SessionQueue::with_depth_cap`]) was already full at arrival, so
    /// the request was rejected without queueing at all.
    QueueFull,
    /// The queue's grant-pace estimator predicted the wait would outlive
    /// the request's patience ([`SessionQueue::with_wait_prediction`]),
    /// so the request was rejected at arrival instead of timing out later.
    PredictedLate,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::AdmissionTimeout => "admission_timeout",
            ShedReason::QueueFull => "queue_full",
            ShedReason::PredictedLate => "predicted_late",
        }
    }
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Why a session ended without a makespan (the module docs' state
/// machine; every variant is terminal and exactly-once).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// An op's work closure panicked; the payload is its panic message.
    OpPanicked { node: NodeId, payload: String },
    /// [`SessionHandle::cancel`] was observed at pop time.
    Cancelled,
    /// The [`Fleet::submit_with_deadline`] deadline passed before the
    /// session quiesced (checked cooperatively at pop time).
    DeadlineExceeded,
    /// The fleet watchdog failed this session after observing no dispatch
    /// progress anywhere on the fleet for its full stall window.
    Stalled,
    /// The request was rejected at admission ([`SessionQueue`]) and never
    /// became a fleet session; serving frontends surface it through the
    /// same error type so every request lands in exactly one outcome
    /// class.
    Shed { reason: ShedReason },
}

impl fmt::Display for SessionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SessionError::OpPanicked { node, payload } => {
                write!(f, "op {node} panicked: {payload}")
            }
            SessionError::Cancelled => write!(f, "session cancelled"),
            SessionError::DeadlineExceeded => write!(f, "session deadline exceeded"),
            SessionError::Stalled => {
                write!(f, "session made no progress (failed by the fleet watchdog)")
            }
            SessionError::Shed { reason } => {
                write!(f, "request shed at admission: {reason}")
            }
        }
    }
}

impl std::error::Error for SessionError {}

/// What a faulted fleet reports from [`Fleet::shutdown`] instead of
/// aborting: which fleet threads panicked outright (a runtime bug — op
/// panics are caught and never unwind an executor) and how many sessions
/// failed, plus the final totals so callers can still account for the
/// work that did happen.
#[derive(Debug, Clone)]
pub struct FleetError {
    /// Panic messages of fleet threads that did not join cleanly.
    pub panicked_threads: Vec<String>,
    /// Sessions that ended in [`SessionError::OpPanicked`] or
    /// [`SessionError::Stalled`].
    pub sessions_failed: u64,
    /// Final counter snapshot (what [`Fleet::shutdown`] would have
    /// returned on a healthy fleet).
    pub totals: FleetTotals,
}

impl fmt::Display for FleetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "fleet shut down after faults: {} session(s) failed, {} fleet thread(s) panicked",
            self.sessions_failed,
            self.panicked_threads.len()
        )?;
        for msg in &self.panicked_threads {
            write!(f, "; thread panic: {msg}")?;
        }
        Ok(())
    }
}

impl std::error::Error for FleetError {}

/// Render a panic payload the way `std` would print it.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A session's work closure: borrowed for the plain [`Fleet::submit`]
/// path (zero allocation, the `ThreadedGraphi` hot path), owned for
/// callers that build per-session closures inside the fleet scope
/// ([`Fleet::submit_owned`], which `graphi serve` uses for per-request
/// fault plans).
enum SessionWork<'env> {
    Borrowed(&'env (dyn Fn(NodeId) + Send + Sync)),
    Owned(Arc<dyn Fn(NodeId) + Send + Sync + 'env>),
    /// Width-aware closure for moldable sessions
    /// ([`Fleet::submit_moldable`]): called as `work(node, rank, width)`
    /// once per seated gang member, the leader being rank 0. A width-1
    /// formation calls it exactly once, as `work(node, 0, 1)`.
    Moldable(Arc<dyn Fn(NodeId, u32, u32) + Send + Sync + 'env>),
}

impl SessionWork<'_> {
    #[inline]
    fn call(&self, node: NodeId, rank: u32, width: u32) {
        match self {
            SessionWork::Borrowed(f) => f(node),
            SessionWork::Owned(f) => f(node),
            SessionWork::Moldable(f) => f(node, rank, width),
        }
    }
}

/// One in-flight (or just-finished) graph execution.
///
/// Owned behind an `Arc` by the submitting client and by any executor
/// whose slot cache still references it; all runtime state is per-session
/// so two sessions never contend on anything but the deques themselves.
struct SessionState<'env> {
    slot: u8,
    /// Monotone fleet-wide submission sequence number (1-based); names
    /// the session in exported traces and steal events.
    seq: u64,
    /// Submit instant as µs since the fleet epoch ([`FleetShared::t0`]),
    /// re-basing this session's records onto the shared timeline.
    submitted_at_us: f64,
    graph: &'env Graph,
    levels: Arc<[f64]>,
    /// Per-node gang widths ([`Fleet::submit_moldable`]); `None` — the
    /// plain submit paths — packs every entry at width 1, bit-identical
    /// to the pre-moldable key layout.
    widths: Option<Arc<[u8]>>,
    work: SessionWork<'env>,
    deps: AtomicDepTracker,
    /// Session epoch: records and the wall clock are relative to submit.
    t0: Instant,
    /// Cooperative deadline ([`Fleet::submit_with_deadline`]), checked at
    /// pop time.
    deadline: Option<Instant>,
    /// Per-executor record buckets (each executor locks only its own).
    records: Vec<Mutex<Vec<OpRecord>>>,
    dispatches: AtomicU64,
    steals: AtomicU64,
    cross_domain_steals: AtomicU64,
    /// Entries alive for this session: queued in a deque / the injector /
    /// the scheduler heap / a ring, **or** currently being processed by a
    /// thread that has not retired them yet. The retire that drains this
    /// to zero releases the slot — the count-gated recycling that makes
    /// slot reuse ABA-free (module docs).
    live_entries: AtomicUsize,
    /// Terminal latch: exactly one of [`finish_session`] / [`fail_session`]
    /// wins the CAS and writes `outcome`.
    terminal: AtomicBool,
    /// Terminal-with-error: remaining entries are discarded at pop time.
    poisoned: AtomicBool,
    /// [`SessionHandle::cancel`] was requested (acted on at pop time).
    cancel_requested: AtomicBool,
    /// `Some(Ok(wall_us))` or `Some(Err(_))` once terminal; guarded by
    /// `done_cv`.
    outcome: Mutex<Option<Result<f64, SessionError>>>,
    done_cv: Condvar,
}

impl SessionState<'_> {
    /// Pack the deque key for one of this session's nodes, folding in the
    /// node's requested gang width (1 for plain sessions). Every seeding
    /// and successor-resolution site goes through this, so a session's
    /// widths apply uniformly in both dispatch modes.
    #[inline]
    fn pack_key(&self, node: NodeId) -> u64 {
        let w = match &self.widths {
            Some(w) => w[node as usize] as u32,
            None => 1,
        };
        pack_session_entry_wide(self.levels[node as usize], self.slot, node, w)
    }
}

/// One session slot of the registry: a monotone install sequence number
/// (for executor-local caching) plus the installed session.
struct SlotCell<'env> {
    seq: AtomicU64,
    state: Mutex<Option<Arc<SessionState<'env>>>>,
}

/// Everything the fleet threads share.
struct FleetShared<'env> {
    executors: usize,
    buffer_depth: usize,
    domains: DomainMap,
    // decentralized: per-executor deques + the submission injector
    deques: Vec<WorkStealDeque>,
    injector: Mutex<BinaryHeap<u64>>,
    /// Racy emptiness hint so idle sweeps skip the injector lock.
    injector_len: AtomicUsize,
    // centralized: scheduler-owned rings + the shared completion queue
    rings: Vec<SpscRing<u64>>,
    done_q: MpscQueue<(u32, u64)>,
    installs: Mutex<Vec<Arc<SessionState<'env>>>>,
    installs_pending: AtomicBool,
    /// Wakes the centralized scheduler (completions, installs, shutdown).
    sched_events: EventCounter,
    /// Wakes executors (new deque/injector/ring work, shutdown).
    events: EventCounter,
    shutdown: AtomicBool,
    /// One gang-recruitment post per executor (module docs); only the
    /// owning executor opens its post, any idle peer may join.
    gangs: Vec<GangPost>,
    slots: Vec<SlotCell<'env>>,
    free_slots: Mutex<Vec<u8>>,
    slot_available: Condvar,
    next_seq: AtomicU64,
    active_sessions: AtomicUsize,
    counters: Counters,
    /// Fleet epoch: [`FleetEvent`] timestamps and session submit offsets
    /// share this clock, so one exported timeline lines everything up.
    t0: Instant,
    /// Per-lane event sinks for the Chrome-trace exporter: one per
    /// executor plus one scheduler/fleet lane, each locked only by its
    /// owning thread until [`Fleet::drain_events`] collects them. Empty
    /// (never allocated) unless [`FleetConfig::record_events`] is set.
    event_sinks: Vec<Mutex<Vec<FleetEvent>>>,
    /// Events dropped because a sink hit [`EVENT_SINK_CAP`].
    events_dropped: AtomicU64,
    // watchdog telemetry (one cell per executor)
    /// Last packed key each executor acquired (`u64::MAX` = none yet).
    last_key: Vec<AtomicU64>,
    /// Executor is inside a work closure right now.
    busy: Vec<AtomicBool>,
    /// Executor is parked on the eventcount right now.
    parked: Vec<AtomicBool>,
}

impl<'env> FleetShared<'env> {
    fn new(config: &FleetConfig) -> FleetShared<'env> {
        let n = config.executors;
        FleetShared {
            executors: n,
            buffer_depth: config.buffer_depth,
            domains: config.numa.clone().unwrap_or_else(|| DomainMap::flat(n)),
            deques: (0..n).map(|_| WorkStealDeque::new(config.deque_capacity)).collect(),
            injector: Mutex::new(BinaryHeap::new()),
            injector_len: AtomicUsize::new(0),
            rings: (0..n).map(|_| SpscRing::new(config.buffer_depth)).collect(),
            // bound on un-drained completions: each executor holds at most
            // `buffer_depth` ops it could have finished before the
            // scheduler drains (push degrades to a bounded retry anyway)
            done_q: MpscQueue::new(n * config.buffer_depth + n + 8),
            installs: Mutex::new(Vec::new()),
            installs_pending: AtomicBool::new(false),
            sched_events: EventCounter::new(),
            events: EventCounter::new(),
            shutdown: AtomicBool::new(false),
            gangs: (0..n).map(|_| GangPost::new()).collect(),
            slots: (0..config.max_sessions)
                .map(|_| SlotCell { seq: AtomicU64::new(0), state: Mutex::new(None) })
                .collect(),
            // pop from the end ⇒ low slots are handed out first
            free_slots: Mutex::new((0..config.max_sessions).rev().map(|s| s as u8).collect()),
            slot_available: Condvar::new(),
            next_seq: AtomicU64::new(0),
            active_sessions: AtomicUsize::new(0),
            counters: Counters::default(),
            t0: Instant::now(),
            event_sinks: if config.record_events {
                (0..=n).map(|_| Mutex::new(Vec::new())).collect()
            } else {
                Vec::new()
            },
            events_dropped: AtomicU64::new(0),
            last_key: (0..n).map(|_| AtomicU64::new(u64::MAX)).collect(),
            busy: (0..n).map(|_| AtomicBool::new(false)).collect(),
            parked: (0..n).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    fn totals_snapshot(&self) -> FleetTotals {
        FleetTotals {
            dispatches: self.counters.dispatches.load(Ordering::SeqCst),
            steals: self.counters.steals.load(Ordering::SeqCst),
            cross_domain_steals: self.counters.cross_domain_steals.load(Ordering::SeqCst),
            parks: self.counters.parks.load(Ordering::SeqCst),
            sessions_completed: self.counters.sessions_completed.load(Ordering::SeqCst),
            sessions_failed: self.counters.sessions_failed.load(Ordering::SeqCst),
            sessions_cancelled: self.counters.sessions_cancelled.load(Ordering::SeqCst),
            sessions_deadline_missed: self
                .counters
                .sessions_deadline_missed
                .load(Ordering::SeqCst),
            sessions_shed: self.counters.sessions_shed.load(Ordering::SeqCst),
            entries_discarded: self.counters.entries_discarded.load(Ordering::SeqCst),
            gangs_formed: self.counters.gangs_formed.load(Ordering::SeqCst),
            gang_recruits: self.counters.gang_recruits.load(Ordering::SeqCst),
            executor_threads: self.counters.executor_threads.load(Ordering::SeqCst) as u64,
        }
    }

    /// Microseconds since the fleet epoch.
    fn now_us(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e6
    }

    /// Record a scheduling event into lane `lane` (executor index, or
    /// `self.executors` for the scheduler/fleet lane). Lock-light: each
    /// lane's mutex is uncontended — only its owning thread pushes, and
    /// the one cross-thread toucher is the final [`Fleet::drain_events`].
    /// No-op (one branch on an empty `Vec`) when recording is off.
    fn record_event(&self, lane: usize, kind: FleetEventKind) {
        if self.event_sinks.is_empty() {
            return;
        }
        let t_us = self.now_us();
        let mut sink = self.event_sinks[lane].lock().unwrap();
        if sink.len() >= EVENT_SINK_CAP {
            self.events_dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let executor = if lane == self.executors { FLEET_LANE } else { lane as u32 };
        sink.push(FleetEvent { t_us, executor, kind });
    }

    /// Monotone progress stamp for the watchdog: any dispatch, discard,
    /// or terminal transition anywhere on the fleet bumps it.
    fn progress_stamp(&self) -> u64 {
        self.counters.dispatches.load(Ordering::Relaxed)
            + self.counters.entries_discarded.load(Ordering::Relaxed)
            + self.counters.sessions_completed.load(Ordering::Relaxed)
            + self.counters.sessions_failed.load(Ordering::Relaxed)
            + self.counters.sessions_cancelled.load(Ordering::Relaxed)
            + self.counters.sessions_deadline_missed.load(Ordering::Relaxed)
    }
}

/// Resolve a packed key's slot to its live session, through an
/// executor-local cache keyed by the slot's install sequence number.
///
/// Why this is race-free: every live entry holds a unit of its session's
/// live-entry count, and a slot is recycled only once that count drains
/// to zero — so whatever the slot currently holds *is* the entry's
/// session; the cache only avoids re-locking while the sequence number is
/// unchanged. `None` (an entry whose slot is empty) is unreachable by
/// that argument; callers treat it as a stale entry and drop it rather
/// than execute against the wrong session.
fn lookup<'env>(
    shared: &FleetShared<'env>,
    cache: &mut [Option<(u64, Arc<SessionState<'env>>)>],
    slot: u8,
) -> Option<Arc<SessionState<'env>>> {
    let cell = &shared.slots[slot as usize];
    let seq = cell.seq.load(Ordering::Acquire);
    if let Some((cached_seq, state)) = &cache[slot as usize] {
        if *cached_seq == seq {
            return Some(Arc::clone(state));
        }
    }
    let state = cell.state.lock().unwrap().clone()?;
    cache[slot as usize] = Some((seq, Arc::clone(&state)));
    Some(state)
}

/// Final-completion bookkeeping: win the terminal latch, flip the
/// session's outcome to `Ok(wall_µs)`, and wake everyone who might care
/// (waiters, parked fleet threads, the scheduler). The slot itself is
/// released by the retire that drains the live-entry count
/// ([`retire_entry`]), which happens-after this on the healthy path.
fn finish_session<'env>(shared: &FleetShared<'env>, session: &Arc<SessionState<'env>>) {
    if session.terminal.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_err()
    {
        // a fault/cancel/watchdog transition won the race; its bookkeeping
        // stands and this completion is just a late arrival
        return;
    }
    let wall_us = session.t0.elapsed().as_secs_f64() * 1e6;
    shared.active_sessions.fetch_sub(1, Ordering::SeqCst);
    shared.counters.sessions_completed.fetch_add(1, Ordering::Relaxed);
    *session.outcome.lock().unwrap() = Some(Ok(wall_us));
    session.done_cv.notify_all();
    shared.events.notify();
    shared.sched_events.notify();
}

/// Terminal-with-error transition (op panic, cancel, deadline, watchdog):
/// win the terminal latch, poison the session so its remaining entries
/// are discarded at pop time, cancel its dep tracker so racing
/// completions become no-ops, and wake waiters with the structured error.
/// Returns whether this call won the transition.
fn fail_session<'env>(
    shared: &FleetShared<'env>,
    session: &Arc<SessionState<'env>>,
    err: SessionError,
) -> bool {
    if session.terminal.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_err()
    {
        return false;
    }
    session.poisoned.store(true, Ordering::Release);
    session.deps.cancel();
    shared.active_sessions.fetch_sub(1, Ordering::SeqCst);
    match err {
        SessionError::OpPanicked { .. } | SessionError::Stalled => {
            shared.counters.sessions_failed.fetch_add(1, Ordering::Relaxed)
        }
        SessionError::Cancelled => shared.counters.sessions_cancelled.fetch_add(1, Ordering::Relaxed),
        SessionError::DeadlineExceeded => {
            shared.counters.sessions_deadline_missed.fetch_add(1, Ordering::Relaxed)
        }
        // unreachable through the session state machine (a shed request is
        // never submitted); kept total so the accounting stays exhaustive
        SessionError::Shed { .. } => shared.counters.sessions_shed.fetch_add(1, Ordering::Relaxed),
    };
    *session.outcome.lock().unwrap() = Some(Err(err));
    session.done_cv.notify_all();
    // wake parked executors and the scheduler so the poisoned entries
    // drain (each drain retires the count toward the slot release)
    shared.events.notify();
    shared.sched_events.notify();
    true
}

/// Release a terminal session's slot back to the free list. Called
/// exactly once per session, by whoever drains its live-entry count.
fn release_slot<'env>(shared: &FleetShared<'env>, session: &Arc<SessionState<'env>>) {
    *shared.slots[session.slot as usize].state.lock().unwrap() = None;
    shared.free_slots.lock().unwrap().push(session.slot);
    shared.slot_available.notify_all();
}

/// Retire one processed (executed or discarded) entry of `session`. The
/// retire that drains the count to zero observes a terminal session by
/// construction — every non-terminal session has at least one live entry
/// — and releases the slot.
fn retire_entry<'env>(shared: &FleetShared<'env>, session: &Arc<SessionState<'env>>) {
    if session.live_entries.fetch_sub(1, Ordering::AcqRel) == 1 {
        debug_assert!(
            session.terminal.load(Ordering::Acquire),
            "live-entry count drained before a terminal transition"
        );
        release_slot(shared, session);
    }
}

/// Pop-time interception, shared by both dispatch modes: discard the
/// entry if its session is poisoned, and turn a pending cancel or an
/// expired deadline into the terminal transition. Returns `true` when the
/// entry was consumed (discarded and retired) and must not execute.
fn intercept_at_pop<'env>(
    shared: &FleetShared<'env>,
    session: &Arc<SessionState<'env>>,
) -> bool {
    if !session.poisoned.load(Ordering::Acquire) {
        if session.cancel_requested.load(Ordering::Acquire) {
            fail_session(shared, session, SessionError::Cancelled);
        } else if session.deadline.is_some_and(|d| Instant::now() >= d) {
            fail_session(shared, session, SessionError::DeadlineExceeded);
        } else {
            return false;
        }
    }
    shared.counters.entries_discarded.fetch_add(1, Ordering::Relaxed);
    retire_entry(shared, session);
    true
}

/// Decentralized acquisition sweep for executor `e`: own deque's LIFO end,
/// then the owner-local spill (deque-overflow fallback), then the
/// session injector, then the NUMA-ranked highest-priority steal.
fn acquire(shared: &FleetShared<'_>, e: usize, spill: &mut Vec<u64>) -> Option<(u64, Acquire)> {
    if let Some(key) = shared.deques[e].pop() {
        return Some((key, Acquire::LocalPop));
    }
    if let Some(key) = spill.pop() {
        return Some((key, Acquire::LocalPop));
    }
    if shared.injector_len.load(Ordering::Acquire) > 0 {
        let mut inj = shared.injector.lock().unwrap();
        let got = inj.pop();
        shared.injector_len.store(inj.len(), Ordering::Release);
        drop(inj);
        if let Some(key) = got {
            return Some((key, Acquire::LocalPop));
        }
    }
    worksteal::steal_highest_numa(&shared.deques, e, &shared.domains)
}

/// Run `node` as a gang leader on executor `e` — its popped entry asked
/// for `target > 1` executors. Opens the executor's post, recruits for a
/// bounded spin, closes at whatever width materialized (possibly 1), runs
/// rank 0, and holds the post until every seated member reported done.
/// Returns the leader closure's own result; a member panic fails the
/// session directly from the member's thread.
fn run_as_gang_leader<'env>(
    shared: &FleetShared<'env>,
    e: usize,
    session: &Arc<SessionState<'env>>,
    key: u64,
    node: NodeId,
    target: u32,
) -> std::thread::Result<()> {
    let post = &shared.gangs[e];
    debug_assert_eq!(post.state.load(Ordering::Relaxed), GANG_IDLE);
    let epoch = (post.joined.load(Ordering::Relaxed) >> GANG_COUNT_BITS).wrapping_add(1);
    post.done.store(0, Ordering::Relaxed);
    post.key.store(key, Ordering::Relaxed);
    post.joined.store(epoch << GANG_COUNT_BITS, Ordering::Relaxed);
    post.state.store(GANG_OPEN, Ordering::Release);
    // parked peers must hear about the opening; idle-spinning peers see
    // the open state on their next scan anyway
    shared.events.notify();
    let want = target - 1;
    for i in 0..GANG_SPIN {
        if (post.joined.load(Ordering::Acquire) & GANG_COUNT_MASK) as u32 >= want {
            break;
        }
        // occasional yields so would-be members on an oversubscribed
        // machine actually get scheduled inside the recruitment window
        if i & 1023 == 1023 {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
        }
    }
    // close with whoever made it: a gang shrinks rather than waits. A
    // member whose join lands after this load observes the epoch-tagged
    // close below with `rank ≥ width` and leaves silently.
    let joined = (post.joined.load(Ordering::Acquire) & GANG_COUNT_MASK) as u32;
    let width = joined.min(want) + 1;
    post.closed.store((epoch << GANG_COUNT_BITS) | width as u64, Ordering::Release);
    post.state.store(GANG_RUNNING, Ordering::Release);
    if width > 1 {
        shared.counters.gangs_formed.fetch_add(1, Ordering::Relaxed);
        shared.counters.gang_recruits.fetch_add((width - 1) as u64, Ordering::Relaxed);
    }
    let result = catch_unwind(AssertUnwindSafe(|| session.work.call(node, 0, width)));
    // wait for every seated member even if rank 0 panicked: the post (and
    // the entry members resolve their session through) must not be
    // reusable while a member still runs against it
    let mut spins = 0u32;
    while post.done.load(Ordering::Acquire) < width - 1 {
        spins += 1;
        if spins < 1 << 8 {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
    post.state.store(GANG_IDLE, Ordering::Release);
    result
}

/// Idle-executor side of gang formation: scan the other executors' posts
/// and serve at most one open recruitment. Returns `true` when this
/// executor joined a formation (seated or turned away) — the caller
/// should reset its backoff and rescan for work, exactly as if it had
/// found an entry.
fn try_join_gang<'env>(
    shared: &FleetShared<'env>,
    e: usize,
    cache: &mut [Option<(u64, Arc<SessionState<'env>>)>],
) -> bool {
    let n = shared.executors;
    for off in 1..n {
        let p = (e + off) % n;
        let post = &shared.gangs[p];
        if post.state.load(Ordering::Acquire) != GANG_OPEN {
            continue;
        }
        let w0 = post.joined.load(Ordering::Acquire);
        if post.joined.compare_exchange(w0, w0 + 1, Ordering::AcqRel, Ordering::Acquire).is_err() {
            // a peer's join won the word, or the formation retired and
            // the epoch half moved (the ABA guard) — scan on
            continue;
        }
        let epoch = w0 >> GANG_COUNT_BITS;
        let rank = ((w0 & GANG_COUNT_MASK) as u32) + 1;
        // wait for the close of *our* formation (epoch-tagged); a seated
        // member never waits long — the leader's recruitment spin is
        // bounded — and an unseated one exits on the first newer epoch
        let width = loop {
            let c = post.closed.load(Ordering::Acquire);
            match (c >> GANG_COUNT_BITS).cmp(&epoch) {
                std::cmp::Ordering::Less => std::hint::spin_loop(),
                std::cmp::Ordering::Equal => break (c & GANG_COUNT_MASK) as u32,
                // the formation closed and fully retired before our join
                // landed: we never had a seat and owe no `done`
                std::cmp::Ordering::Greater => return true,
            }
        };
        if rank >= width {
            // joined after the close-read: turned away (`done` counts
            // seated members only)
            return true;
        }
        // seat secured: the leader blocks on our `done`, so the post and
        // the key's slot (pinned by the leader's un-retired entry) are
        // stable until we report
        let key = post.key.load(Ordering::Acquire);
        let slot = session_entry_slot(key);
        let node = session_entry_node(key);
        if let Some(session) = lookup(shared, cache, slot) {
            shared.busy[e].store(true, Ordering::Relaxed);
            let result =
                catch_unwind(AssertUnwindSafe(|| session.work.call(node, rank, width)));
            shared.busy[e].store(false, Ordering::Relaxed);
            if let Err(payload) = result {
                // a member panic poisons the session like any op panic;
                // the leader still writes the gang's one OpRecord and
                // retires the entry
                fail_session(
                    shared,
                    &session,
                    SessionError::OpPanicked { node, payload: panic_message(payload) },
                );
            }
        }
        post.done.fetch_add(1, Ordering::Release);
        return true;
    }
    false
}

/// Decentralized executor body: PR-3's executor-side successor resolution,
/// now multi-session (the key's slot routes every touch to the right
/// session's tracker, records, and counters).
fn executor_decentralized<'env>(shared: &FleetShared<'env>, e: usize) {
    let mut cache: Vec<Option<(u64, Arc<SessionState<'env>>)>> =
        (0..shared.slots.len()).map(|_| None).collect();
    let mut spill: Vec<u64> = Vec::new();
    let mut batch: Vec<u64> = Vec::new();
    let mut backoff = Backoff::new();
    loop {
        // park-stage registration before the sweep — the eventcount's
        // lost-wakeup guard (see crate::engine::backoff)
        let prepared = (backoff.stage() == BackoffStage::Park).then(|| shared.events.prepare());
        match acquire(shared, e, &mut spill) {
            Some((key, kind)) => {
                if prepared.is_some() {
                    shared.events.cancel();
                }
                backoff.reset();
                let slot = session_entry_slot(key);
                let node = session_entry_node(key);
                shared.last_key[e].store(key, Ordering::Relaxed);
                let Some(session) = lookup(shared, &mut cache, slot) else {
                    // unreachable by the count-gated recycling argument,
                    // but a stale entry must be dropped, never executed
                    // against whatever session owns the slot now
                    shared.counters.entries_discarded.fetch_add(1, Ordering::Relaxed);
                    continue;
                };
                if intercept_at_pop(shared, &session) {
                    cache[slot as usize] = None;
                    continue;
                }
                shared.counters.dispatches.fetch_add(1, Ordering::Relaxed);
                session.dispatches.fetch_add(1, Ordering::Relaxed);
                if kind.is_steal() {
                    shared.counters.steals.fetch_add(1, Ordering::Relaxed);
                    session.steals.fetch_add(1, Ordering::Relaxed);
                    if kind == Acquire::StealCrossDomain {
                        shared.counters.cross_domain_steals.fetch_add(1, Ordering::Relaxed);
                        session.cross_domain_steals.fetch_add(1, Ordering::Relaxed);
                    }
                    shared.record_event(
                        e,
                        FleetEventKind::Steal {
                            session: session.seq,
                            cross_domain: kind == Acquire::StealCrossDomain,
                        },
                    );
                }
                let w_target = session_entry_width(key);
                let start = session.t0.elapsed().as_secs_f64() * 1e6;
                shared.busy[e].store(true, Ordering::Relaxed);
                let result = if w_target > 1 {
                    run_as_gang_leader(shared, e, &session, key, node, w_target)
                } else {
                    catch_unwind(AssertUnwindSafe(|| session.work.call(node, 0, 1)))
                };
                shared.busy[e].store(false, Ordering::Relaxed);
                let end = session.t0.elapsed().as_secs_f64() * 1e6;
                if let Err(payload) = result {
                    fail_session(
                        shared,
                        &session,
                        SessionError::OpPanicked { node, payload: panic_message(payload) },
                    );
                    retire_entry(shared, &session);
                    cache[slot as usize] = None;
                    continue;
                }
                session.records[e]
                    .lock()
                    .unwrap()
                    .push(OpRecord { node, executor: e as u32, start_us: start, end_us: end });
                // resolve successors against the *session's* tracker and
                // push them onto the own deque, ascending so the LIFO end
                // is the batch's highest-level op; a session poisoned
                // while this op ran propagates nothing further
                batch.clear();
                let mut last = false;
                if !session.poisoned.load(Ordering::Acquire) {
                    last = session.deps.complete(session.graph, node, |s| {
                        batch.push(session.pack_key(s));
                    });
                }
                if !batch.is_empty() {
                    // count the successors live *before* exposing them:
                    // our own un-retired entry keeps the count nonzero
                    // throughout, so the slot cannot recycle under us
                    session.live_entries.fetch_add(batch.len(), Ordering::AcqRel);
                    batch.sort_unstable();
                    let mut spilled = false;
                    for &k in &batch {
                        if shared.deques[e].push(k).is_err() {
                            spill.push(k);
                            spilled = true;
                        }
                    }
                    if spilled {
                        spill.sort_unstable();
                    }
                    shared.events.notify();
                }
                if last {
                    finish_session(shared, &session);
                }
                retire_entry(shared, &session);
                if last {
                    cache[slot as usize] = None;
                }
            }
            None => {
                if shared.shutdown.load(Ordering::Acquire) {
                    if prepared.is_some() {
                        shared.events.cancel();
                    }
                    return;
                }
                // no entries anywhere: serve an open gang recruitment
                // before backing off (joining counts as finding work)
                if try_join_gang(shared, e, &mut cache) {
                    if prepared.is_some() {
                        shared.events.cancel();
                    }
                    backoff.reset();
                    continue;
                }
                match backoff.next() {
                    BackoffStage::Spin => std::hint::spin_loop(),
                    BackoffStage::Yield => std::thread::yield_now(),
                    BackoffStage::Park => {
                        // about to sleep: drop cached session Arcs so a
                        // finished session's O(nodes) tracker/levels are
                        // not pinned across an idle period (the cache
                        // rebuilds with one registry lock per slot on the
                        // next burst)
                        cache.iter_mut().for_each(|c| *c = None);
                        let observed = prepared.expect("park stage registers before the sweep");
                        shared.parked[e].store(true, Ordering::Relaxed);
                        if shared.events.park(observed, PARK_TIMEOUT) {
                            shared.counters.parks.fetch_add(1, Ordering::Relaxed);
                            shared.record_event(e, FleetEventKind::Park);
                        }
                        shared.parked[e].store(false, Ordering::Relaxed);
                    }
                }
            }
        }
    }
}

/// Centralized executor body (Algorithm 2): poll the own ring, execute,
/// report the completion back to the scheduler thread. Entries the
/// executor consumes without a real completion (poisoned discards, the
/// panicking op itself) still report back, tagged [`DONE_DISCARDED`], so
/// the scheduler's inflight/availability bookkeeping never leaks a ring
/// slot — but the executor retires those entries itself.
fn executor_centralized<'env>(shared: &FleetShared<'env>, e: usize) {
    let mut cache: Vec<Option<(u64, Arc<SessionState<'env>>)>> =
        (0..shared.slots.len()).map(|_| None).collect();
    let mut backoff = Backoff::new();
    loop {
        let prepared = (backoff.stage() == BackoffStage::Park).then(|| shared.events.prepare());
        if let Some(key) = shared.rings[e].pop() {
            if prepared.is_some() {
                shared.events.cancel();
            }
            backoff.reset();
            let slot = session_entry_slot(key);
            let node = session_entry_node(key);
            shared.last_key[e].store(key, Ordering::Relaxed);
            let Some(session) = lookup(shared, &mut cache, slot) else {
                shared.counters.entries_discarded.fetch_add(1, Ordering::Relaxed);
                push_done(shared, e as u32 | DONE_DISCARDED, key);
                shared.sched_events.notify();
                continue;
            };
            if intercept_at_pop(shared, &session) {
                cache[slot as usize] = None;
                push_done(shared, e as u32 | DONE_DISCARDED, key);
                shared.sched_events.notify();
                continue;
            }
            let w_target = session_entry_width(key);
            let start = session.t0.elapsed().as_secs_f64() * 1e6;
            shared.busy[e].store(true, Ordering::Relaxed);
            let result = if w_target > 1 {
                run_as_gang_leader(shared, e, &session, key, node, w_target)
            } else {
                catch_unwind(AssertUnwindSafe(|| session.work.call(node, 0, 1)))
            };
            shared.busy[e].store(false, Ordering::Relaxed);
            let end = session.t0.elapsed().as_secs_f64() * 1e6;
            match result {
                Err(payload) => {
                    fail_session(
                        shared,
                        &session,
                        SessionError::OpPanicked { node, payload: panic_message(payload) },
                    );
                    retire_entry(shared, &session);
                    cache[slot as usize] = None;
                    push_done(shared, e as u32 | DONE_DISCARDED, key);
                }
                Ok(()) => {
                    session.records[e]
                        .lock()
                        .unwrap()
                        .push(OpRecord { node, executor: e as u32, start_us: start, end_us: end });
                    push_done(shared, e as u32, key);
                }
            }
            shared.sched_events.notify();
        } else if shared.shutdown.load(Ordering::Acquire) {
            if prepared.is_some() {
                shared.events.cancel();
            }
            return;
        } else if try_join_gang(shared, e, &mut cache) {
            // an empty ring + an open peer post: recruitment is how the
            // centralized fleet lends idle executors to wide ops without
            // the scheduler's involvement
            if prepared.is_some() {
                shared.events.cancel();
            }
            backoff.reset();
        } else {
            match backoff.next() {
                BackoffStage::Spin => std::hint::spin_loop(),
                BackoffStage::Yield => std::thread::yield_now(),
                BackoffStage::Park => {
                    // idle: drop cached session Arcs (see the
                    // decentralized loop for the rationale)
                    cache.iter_mut().for_each(|c| *c = None);
                    let observed = prepared.expect("park stage registers before polling");
                    shared.parked[e].store(true, Ordering::Relaxed);
                    if shared.events.park(observed, PARK_TIMEOUT) {
                        shared.counters.parks.fetch_add(1, Ordering::Relaxed);
                        shared.record_event(e, FleetEventKind::Park);
                    }
                    shared.parked[e].store(false, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Report a completion (or a discard) to the scheduler; the queue is
/// sized for every in-flight op, so degrade to a bounded retry rather
/// than ever losing one.
fn push_done(shared: &FleetShared<'_>, tag: u32, key: u64) {
    let mut item = (tag, key);
    while let Err(back) = shared.done_q.push(item) {
        item = back;
        std::thread::yield_now();
    }
}

/// Centralized scheduler body (Algorithm 1), multi-session: one max-heap
/// of packed keys orders ready ops CP-first *across* sessions, installs
/// seed new sessions' sources, completions resolve against the owning
/// session's tracker.
fn scheduler_loop<'env>(shared: &FleetShared<'env>) {
    let n_exec = shared.executors;
    let depth = shared.buffer_depth;
    let mut ready: BinaryHeap<u64> = BinaryHeap::new();
    let mut cache: Vec<Option<(u64, Arc<SessionState<'env>>)>> =
        (0..shared.slots.len()).map(|_| None).collect();
    let mut inflight = vec![0usize; n_exec];
    let mut available = IdleBitmap::new(n_exec);
    let mut completions: Vec<(u32, u64)> = Vec::with_capacity(n_exec * 2 + 8);
    let mut backoff = Backoff::new();
    loop {
        let prepared =
            (backoff.stage() == BackoffStage::Park).then(|| shared.sched_events.prepare());
        let mut progressed = false;
        // newly submitted sessions: seed their sources into the heap
        if shared.installs_pending.swap(false, Ordering::AcqRel) {
            let pending: Vec<Arc<SessionState<'env>>> = {
                let mut q = shared.installs.lock().unwrap();
                q.drain(..).collect()
            };
            for session in &pending {
                for s in session.graph.sources() {
                    ready.push(session.pack_key(s));
                }
                progressed = true;
            }
        }
        // drain the shared completion queue in one batch
        completions.clear();
        shared.done_q.pop_batch(&mut completions, usize::MAX);
        for &(tag, key) in completions.iter() {
            let discarded = tag & DONE_DISCARDED != 0;
            let e = (tag & !DONE_DISCARDED) as usize;
            inflight[e] -= 1;
            if inflight[e] == depth - 1 && !available.is_idle(e) {
                available.set_idle(e);
            }
            progressed = true;
            if discarded {
                // the executor consumed and retired this entry itself
                // (poisoned discard or the panicking op); only the
                // inflight/availability bookkeeping above was owed
                continue;
            }
            let slot = session_entry_slot(key);
            let node = session_entry_node(key);
            let Some(session) = lookup(shared, &mut cache, slot) else {
                shared.counters.entries_discarded.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            if session.poisoned.load(Ordering::Acquire) {
                // the op executed, but its session faulted meanwhile —
                // drop the completion instead of resolving successors
                shared.counters.entries_discarded.fetch_add(1, Ordering::Relaxed);
                retire_entry(shared, &session);
                cache[slot as usize] = None;
                continue;
            }
            let mut readied = 0usize;
            let last = session.deps.complete(session.graph, node, |s| {
                ready.push(session.pack_key(s));
                readied += 1;
            });
            if readied > 0 {
                // counted before this entry retires: the count stays
                // nonzero, so the slot cannot recycle mid-resolution
                session.live_entries.fetch_add(readied, Ordering::AcqRel);
            }
            if last {
                finish_session(shared, &session);
            }
            retire_entry(shared, &session);
            if last {
                cache[slot as usize] = None;
            }
        }
        // dispatch: max-key ops → first available executor (bit-scan);
        // poisoned entries are discarded here instead of burning a ring
        // slot on a dead session
        let mut pushed_any = false;
        while !ready.is_empty() && available.any_idle() {
            let e = available.first_idle().expect("any_idle checked");
            while inflight[e] < depth {
                let Some(key) = ready.pop() else { break };
                let slot = session_entry_slot(key);
                let Some(session) = lookup(shared, &mut cache, slot) else {
                    shared.counters.entries_discarded.fetch_add(1, Ordering::Relaxed);
                    progressed = true;
                    continue;
                };
                if session.poisoned.load(Ordering::Acquire) {
                    shared.counters.entries_discarded.fetch_add(1, Ordering::Relaxed);
                    retire_entry(shared, &session);
                    cache[slot as usize] = None;
                    progressed = true;
                    continue;
                }
                shared.rings[e].push(key).expect("availability bit ⇒ ring space");
                inflight[e] += 1;
                pushed_any = true;
                shared.counters.dispatches.fetch_add(1, Ordering::Relaxed);
                session.dispatches.fetch_add(1, Ordering::Relaxed);
            }
            if inflight[e] >= depth {
                available.set_busy(e);
            } else {
                break; // heap drained with buffer room to spare
            }
        }
        if pushed_any {
            shared.events.notify();
            progressed = true;
        }
        if progressed {
            if prepared.is_some() {
                shared.sched_events.cancel();
            }
            backoff.reset();
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            if prepared.is_some() {
                shared.sched_events.cancel();
            }
            // shutdown is contractually called only after every session
            // quiesced; if that contract is broken (handle dropped
            // without wait, panic unwinding a fleet), exit anyway —
            // abandoning the sessions loudly beats deadlocking the
            // join in `Fleet::halt` (executors are exiting too, so no
            // completion could ever drain the remaining ops)
            let abandoned = shared.active_sessions.load(Ordering::SeqCst);
            if abandoned > 0 {
                crate::log_warn!(
                    "fleet scheduler stopping with {abandoned} session(s) still in flight \
                     (shutdown before wait?)"
                );
            }
            return;
        }
        match backoff.next() {
            BackoffStage::Spin => std::hint::spin_loop(),
            BackoffStage::Yield => std::thread::yield_now(),
            BackoffStage::Park => {
                let observed = prepared.expect("park stage registers before polling");
                if shared.sched_events.park(observed, PARK_TIMEOUT) {
                    shared.counters.parks.fetch_add(1, Ordering::Relaxed);
                    shared.record_event(shared.executors, FleetEventKind::Park);
                }
            }
        }
    }
}

/// Emit the watchdog's diagnostic dump: per-executor last acquired entry,
/// deque depth, busy/parked state, plus the injector backlog — enough to
/// tell a hung op (one executor busy forever on one key) from a runtime
/// livelock (everyone parked with work queued).
fn dump_stall_diagnostics(shared: &FleetShared<'_>) {
    let active = shared.active_sessions.load(Ordering::SeqCst);
    crate::log_warn!(
        "fleet watchdog: no dispatch progress with {active} active session(s); executor state:"
    );
    for e in 0..shared.executors {
        let key = shared.last_key[e].load(Ordering::Relaxed);
        let last = if key == u64::MAX {
            "-".to_string()
        } else {
            format!("s{}/n{}", session_entry_slot(key), session_entry_node(key))
        };
        crate::log_warn!(
            "  executor {e}: last={last} deque_depth={} busy={} parked={}",
            shared.deques[e].len(),
            shared.busy[e].load(Ordering::Relaxed),
            shared.parked[e].load(Ordering::Relaxed),
        );
    }
    crate::log_warn!(
        "  injector backlog: {}",
        shared.injector_len.load(Ordering::Acquire)
    );
}

/// Watchdog body ([`FleetConfig::watchdog`]): sample the fleet's progress
/// stamp a few times per stall window; when sessions are active but the
/// stamp has not moved for a full window, dump diagnostics and fail every
/// installed session with [`SessionError::Stalled`] so waiters wake.
///
/// An executor mid-op is deliberately *not* treated as progress — a hung
/// op is exactly the stall this exists to catch. The window must
/// therefore exceed the longest legitimate op. A false positive degrades
/// gracefully: the failed sessions' remaining entries drain as discards
/// and the fleet keeps serving new submissions.
fn watchdog_loop(shared: &FleetShared<'_>, stall_after: Duration) {
    let tick = (stall_after / 4).clamp(Duration::from_millis(5), Duration::from_millis(200));
    // sleep in short slices so `halt()` never waits a whole tick to join
    // the watchdog (stress suites tear fleets down thousands of times)
    let slice = tick.min(Duration::from_millis(5));
    let mut last_stamp = shared.progress_stamp();
    let mut stalled_for = Duration::ZERO;
    loop {
        let mut slept = Duration::ZERO;
        while slept < tick {
            std::thread::sleep(slice);
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            slept += slice;
        }
        let stamp = shared.progress_stamp();
        if stamp != last_stamp || shared.active_sessions.load(Ordering::SeqCst) == 0 {
            last_stamp = stamp;
            stalled_for = Duration::ZERO;
            continue;
        }
        stalled_for += tick;
        if stalled_for < stall_after {
            continue;
        }
        dump_stall_diagnostics(shared);
        for cell in &shared.slots {
            let installed = cell.state.lock().unwrap().clone();
            if let Some(session) = installed {
                fail_session(shared, &session, SessionError::Stalled);
            }
        }
        stalled_for = Duration::ZERO;
        last_stamp = shared.progress_stamp();
    }
}

/// A long-lived executor fleet: threads spawned once, sessions submitted
/// many times. Scoped to a [`std::thread::Scope`] so sessions may borrow
/// anything that outlives the scope (graphs, work closures) with zero
/// `unsafe` — the pattern `ThreadedGraphi::run` and `graphi serve` both
/// build on.
pub struct Fleet<'scope, 'env> {
    shared: Arc<FleetShared<'env>>,
    handles: Vec<ScopedJoinHandle<'scope, ()>>,
    config: FleetConfig,
}

impl<'scope, 'env> Fleet<'scope, 'env> {
    /// Spawn the fleet's threads (executors, plus one scheduler thread in
    /// centralized mode). This is the only place threads are created.
    pub fn new(scope: &'scope Scope<'scope, 'env>, config: FleetConfig) -> Fleet<'scope, 'env> {
        assert!(config.executors >= 1, "a fleet needs at least one executor");
        assert!(config.buffer_depth >= 1, "buffer depth must be at least 1");
        assert!(
            (1..=MAX_SESSIONS).contains(&config.max_sessions),
            "max_sessions must be in 1..={MAX_SESSIONS} (8-bit slot field)"
        );
        if let Some(map) = &config.numa {
            assert_eq!(map.len(), config.executors, "one domain per executor");
        }
        let shared = Arc::new(FleetShared::new(&config));
        let mut handles = Vec::with_capacity(config.executors + 1);
        for e in 0..config.executors {
            let sh = Arc::clone(&shared);
            let dispatch = config.dispatch;
            handles.push(scope.spawn(move || {
                sh.counters.executor_threads.fetch_add(1, Ordering::SeqCst);
                match dispatch {
                    DispatchMode::Decentralized => executor_decentralized(&sh, e),
                    DispatchMode::Centralized => executor_centralized(&sh, e),
                }
            }));
        }
        if config.dispatch == DispatchMode::Centralized {
            let sh = Arc::clone(&shared);
            handles.push(scope.spawn(move || scheduler_loop(&sh)));
        }
        if let Some(stall_after) = config.watchdog {
            let sh = Arc::clone(&shared);
            handles.push(scope.spawn(move || watchdog_loop(&sh, stall_after)));
        }
        Fleet { shared, handles, config }
    }

    pub fn executors(&self) -> usize {
        self.config.executors
    }

    pub fn dispatch(&self) -> DispatchMode {
        self.config.dispatch
    }

    /// Executor threads that have ever started on this fleet. Spawned
    /// once at construction: submitting more sessions never grows it.
    pub fn executor_threads_started(&self) -> usize {
        self.shared.counters.executor_threads.load(Ordering::SeqCst)
    }

    /// Sessions currently submitted but not yet quiesced.
    pub fn active_sessions(&self) -> usize {
        self.shared.active_sessions.load(Ordering::SeqCst)
    }

    /// Fleet-lifetime counter snapshot.
    pub fn totals(&self) -> FleetTotals {
        self.shared.totals_snapshot()
    }

    /// Account one request shed at admission. Sheds happen *before* a
    /// session exists (the request never reaches [`Fleet::submit`]), so
    /// the serving frontend reports them into the fleet's totals through
    /// this instead of the session state machine; the counter keeps the
    /// five outcome classes (completed / failed / cancelled /
    /// deadline_missed / shed) conserved against offered requests.
    pub fn record_shed(&self) {
        self.shared.counters.sessions_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Microseconds since the fleet epoch — the clock [`FleetEvent`]
    /// timestamps and [`SessionReport::submitted_at_us`] are measured on.
    pub fn now_us(&self) -> f64 {
        self.shared.now_us()
    }

    /// Collect every recorded scheduling event, sorted by time. Empty
    /// unless [`FleetConfig::record_events`] was set. Call after the last
    /// session of interest has quiesced; events recorded later are lost.
    pub fn drain_events(&self) -> Vec<FleetEvent> {
        let dropped = self.shared.events_dropped.swap(0, Ordering::Relaxed);
        if dropped > 0 {
            crate::log_warn!("fleet event sink overflowed: {dropped} event(s) dropped");
        }
        let mut out = Vec::new();
        for sink in &self.shared.event_sinks {
            out.append(&mut sink.lock().unwrap());
        }
        out.sort_by(|a, b| a.t_us.total_cmp(&b.t_us));
        out
    }

    /// Submit a graph execution. Blocks only if every session slot is
    /// taken (bound memory with a [`SessionQueue`] *before* submitting).
    /// `work(node)` runs on some executor thread for each op,
    /// dependencies respected; `levels` orders ops CP-first within and
    /// across sessions (see the module docs).
    pub fn submit(
        &self,
        graph: &'env Graph,
        levels: impl Into<Arc<[f64]>>,
        work: &'env (dyn Fn(NodeId) + Send + Sync),
    ) -> SessionHandle<'env> {
        self.submit_inner(graph, levels.into(), None, SessionWork::Borrowed(work), None)
    }

    /// [`submit`](Self::submit) with a cooperative deadline: once
    /// `deadline` has elapsed (measured from submission), the session's
    /// remaining entries are discarded at pop time and the waiter gets
    /// [`SessionError::DeadlineExceeded`]. An op already running when the
    /// deadline passes still finishes — cancellation never interrupts a
    /// work closure mid-flight.
    pub fn submit_with_deadline(
        &self,
        graph: &'env Graph,
        levels: impl Into<Arc<[f64]>>,
        work: &'env (dyn Fn(NodeId) + Send + Sync),
        deadline: Duration,
    ) -> SessionHandle<'env> {
        self.submit_inner(graph, levels.into(), None, SessionWork::Borrowed(work), Some(deadline))
    }

    /// [`submit`](Self::submit) with an owned work closure, for callers
    /// that build a distinct closure per session *inside* the fleet's
    /// scope (e.g. per-request fault plans in `graphi serve`) and so
    /// cannot hand out an `'env` borrow of it.
    pub fn submit_owned(
        &self,
        graph: &'env Graph,
        levels: impl Into<Arc<[f64]>>,
        work: Arc<dyn Fn(NodeId) + Send + Sync + 'env>,
        deadline: Option<Duration>,
    ) -> SessionHandle<'env> {
        self.submit_inner(graph, levels.into(), None, SessionWork::Owned(work), deadline)
    }

    /// Submit a **moldable** session: `widths[node]` is the gang width
    /// each op requests (`1..=MAX_WIDTH`, see the module docs' gang
    /// section), and `work(node, rank, width)` runs once per seated gang
    /// member — the popping executor at rank 0, recruits at `1..width`.
    /// The *effective* width is `min(requested, 1 + idle peers at pop)`:
    /// a gang shrinks rather than waits, so any width assignment is safe
    /// on any fleet size. Width-1 nodes take exactly the plain
    /// [`Fleet::submit`] path.
    pub fn submit_moldable(
        &self,
        graph: &'env Graph,
        levels: impl Into<Arc<[f64]>>,
        widths: impl Into<Arc<[u8]>>,
        work: Arc<dyn Fn(NodeId, u32, u32) + Send + Sync + 'env>,
        deadline: Option<Duration>,
    ) -> SessionHandle<'env> {
        let widths = widths.into();
        assert_eq!(widths.len(), graph.len(), "one gang width per node");
        assert!(
            widths.iter().all(|&w| w >= 1 && (w as u32) <= MAX_WIDTH),
            "gang widths must be in 1..={MAX_WIDTH}"
        );
        self.submit_inner(
            graph,
            levels.into(),
            Some(widths),
            SessionWork::Moldable(work),
            deadline,
        )
    }

    fn submit_inner(
        &self,
        graph: &'env Graph,
        levels: Arc<[f64]>,
        widths: Option<Arc<[u8]>>,
        work: SessionWork<'env>,
        deadline: Option<Duration>,
    ) -> SessionHandle<'env> {
        assert_eq!(levels.len(), graph.len(), "one level per node");
        assert!(
            graph.len() < MAX_SESSION_NODES,
            "session graphs are limited to {MAX_SESSION_NODES} nodes by the packed key's node field"
        );
        let shared = &self.shared;
        let slot = {
            let mut free = shared.free_slots.lock().unwrap();
            loop {
                if let Some(s) = free.pop() {
                    break s;
                }
                free = shared.slot_available.wait(free).unwrap();
            }
        };
        let seq = shared.next_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let sources = graph.sources();
        let submitted_at_us = shared.now_us();
        let t0 = Instant::now();
        let state = Arc::new(SessionState {
            slot,
            seq,
            submitted_at_us,
            graph,
            levels,
            widths,
            work,
            deps: AtomicDepTracker::new(graph),
            t0,
            deadline: deadline.map(|d| t0 + d),
            records: (0..self.config.executors).map(|_| Mutex::new(Vec::new())).collect(),
            dispatches: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            cross_domain_steals: AtomicU64::new(0),
            // the seeded sources are the session's first live entries; the
            // count must be up before any of them becomes poppable
            live_entries: AtomicUsize::new(sources.len()),
            terminal: AtomicBool::new(false),
            poisoned: AtomicBool::new(false),
            cancel_requested: AtomicBool::new(false),
            outcome: Mutex::new(None),
            done_cv: Condvar::new(),
        });
        shared.active_sessions.fetch_add(1, Ordering::SeqCst);
        *shared.slots[slot as usize].state.lock().unwrap() = Some(Arc::clone(&state));
        shared.slots[slot as usize].seq.store(seq, Ordering::Release);
        match self.config.dispatch {
            DispatchMode::Decentralized => {
                // submitters are not deque owners — seed through the
                // injector, which executors drain before stealing
                {
                    let mut inj = shared.injector.lock().unwrap();
                    for &s in &sources {
                        inj.push(state.pack_key(s));
                    }
                    shared.injector_len.store(inj.len(), Ordering::Release);
                }
                shared.events.notify();
            }
            DispatchMode::Centralized => {
                // the scheduler re-derives the same source list when it
                // drains the install queue, matching the count above
                shared.installs.lock().unwrap().push(Arc::clone(&state));
                shared.installs_pending.store(true, Ordering::Release);
                shared.sched_events.notify();
            }
        }
        SessionHandle { state, shared: Arc::clone(&self.shared) }
    }

    /// Stop and join every fleet thread; returns the panic messages of
    /// any that did not join cleanly. Op panics are caught on the
    /// executors, so a non-empty return means a fleet-runtime bug, not a
    /// workload fault.
    fn halt(&mut self) -> Vec<String> {
        if self.handles.is_empty() {
            return Vec::new();
        }
        debug_assert_eq!(
            self.shared.active_sessions.load(Ordering::SeqCst),
            0,
            "fleet shutdown with sessions still in flight"
        );
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.events.notify();
        self.shared.sched_events.notify();
        let mut panicked = Vec::new();
        for h in self.handles.drain(..) {
            if let Err(payload) = h.join() {
                panicked.push(panic_message(payload));
            }
        }
        panicked
    }

    /// Stop and join every fleet thread (all sessions must have reached a
    /// terminal state first). `Ok` carries the final counter snapshot; a
    /// fleet that saw failed sessions ([`SessionError::OpPanicked`] /
    /// [`SessionError::Stalled`]) or — a runtime bug — a panicked fleet
    /// thread reports a [`FleetError`] instead of aborting the process,
    /// with the same snapshot inside. Client-initiated terminations
    /// (cancel, deadline) are not faults and do not turn shutdown into an
    /// error. A clean join *is* the no-leaked-threads proof: every handle
    /// is joined here. Calling this with sessions still in flight is a
    /// contract violation: the fleet still exits (threads abandon the
    /// remaining ops with a warning rather than deadlocking the join),
    /// but those sessions never quiesce and their waiters would block
    /// forever.
    pub fn shutdown(mut self) -> Result<FleetTotals, FleetError> {
        let panicked = self.halt();
        let totals = self.shared.totals_snapshot();
        if panicked.is_empty() && totals.sessions_failed == 0 {
            Ok(totals)
        } else {
            Err(FleetError {
                panicked_threads: panicked,
                sessions_failed: totals.sessions_failed,
                totals,
            })
        }
    }
}

impl Drop for Fleet<'_, '_> {
    fn drop(&mut self) {
        let panicked = self.halt();
        if !panicked.is_empty() {
            crate::log_warn!(
                "fleet dropped with {} panicked fleet thread(s): {}",
                panicked.len(),
                panicked.join("; ")
            );
        }
    }
}

/// Handle to one submitted session.
pub struct SessionHandle<'env> {
    state: Arc<SessionState<'env>>,
    shared: Arc<FleetShared<'env>>,
}

/// What a finished session reports back.
#[derive(Debug)]
pub struct SessionReport {
    /// Fleet-wide submission sequence number (1-based).
    pub seq: u64,
    /// Submit instant as µs since the fleet epoch, placing this session's
    /// (submit-relative) records on the fleet's shared timeline.
    pub submitted_at_us: f64,
    /// Submit-to-quiescence wall time, µs.
    pub wall_us: f64,
    /// Per-op records (µs since submit), sorted by start time.
    pub records: Vec<OpRecord>,
    /// Ops dispatched for this session (= its node count).
    pub dispatches: u64,
    /// Of those, acquired by stealing (decentralized fleets).
    pub steals: u64,
    /// Of the steals, cross-NUMA-domain ones.
    pub cross_domain_steals: u64,
}

impl<'env> SessionHandle<'env> {
    /// Has the session reached a terminal state — quiesced, failed,
    /// cancelled, or deadline-missed? (Non-blocking.)
    pub fn is_done(&self) -> bool {
        self.state.outcome.lock().unwrap().is_some()
    }

    /// Fleet-wide submission sequence number (1-based).
    pub fn seq(&self) -> u64 {
        self.state.seq
    }

    /// Submit instant as µs since the fleet epoch (available before
    /// [`wait`](Self::wait), e.g. to timestamp a failed session's
    /// lifecycle in an exported trace).
    pub fn submitted_at_us(&self) -> f64 {
        self.state.submitted_at_us
    }

    /// Request cooperative cancellation. The next of this session's
    /// entries popped anywhere on the fleet performs the terminal
    /// `Cancelled` transition and the rest are discarded; the waiter gets
    /// [`SessionError::Cancelled`]. An op already running is never
    /// interrupted, and a session whose final op completes before any pop
    /// observes the request still reports `Ok` — cancellation races
    /// completion, exactly-once either way.
    pub fn cancel(&self) {
        self.state.cancel_requested.store(true, Ordering::Release);
        // wake parked fleet threads so the pop-side check runs promptly
        self.shared.events.notify();
        self.shared.sched_events.notify();
    }

    /// Block until the session reaches a terminal state. `Ok` merges the
    /// trace and counters (the final completion's release sequence orders
    /// every executor's record writes before the outcome, so the merge is
    /// complete by construction); `Err` is the structured failure — the
    /// records of ops that did run are dropped with the session.
    pub fn wait(self) -> Result<SessionReport, SessionError> {
        let outcome = {
            let mut outcome = self.state.outcome.lock().unwrap();
            loop {
                if let Some(o) = outcome.take() {
                    break o;
                }
                outcome = self.state.done_cv.wait(outcome).unwrap();
            }
        };
        let wall_us = outcome?;
        let mut records: Vec<OpRecord> = Vec::with_capacity(self.state.graph.len());
        for bucket in self.state.records.iter() {
            records.extend(bucket.lock().unwrap().drain(..));
        }
        records.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        Ok(SessionReport {
            seq: self.state.seq,
            submitted_at_us: self.state.submitted_at_us,
            wall_us,
            records,
            dispatches: self.state.dispatches.load(Ordering::SeqCst),
            steals: self.state.steals.load(Ordering::SeqCst),
            cross_domain_steals: self.state.cross_domain_steals.load(Ordering::SeqCst),
        })
    }
}

/// Which key orders blocked admission requests — FIFO tickets generalized
/// to policy-ordered keys.
///
/// Every policy keeps the same head-of-line discipline: only the request
/// the policy ranks first may take freed budget (no bypass), so the §5.1
/// no-starvation argument survives with a per-policy restatement:
///
/// - **Fifo** (default): key = arrival ticket. Strict arrival order; a
///   large session cannot be starved by smaller ones slipping into gaps.
/// - **Priority**: key = (effective class, ticket), lower class first,
///   where the effective class *ages* toward 0 while a request waits
///   ([`SessionQueue::with_priority_aging`]) — a low-priority request is
///   delayed, never starved.
/// - **Edf**: key = (absolute patience deadline, ticket) — earliest
///   deadline first. Starvation is bounded structurally: a request whose
///   deadline passes stops waiting (it times out and sheds), so no
///   request can be bypassed for longer than its own patience.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    #[default]
    Fifo,
    Priority,
    Edf,
}

impl AdmissionPolicy {
    pub const ALL: [AdmissionPolicy; 3] =
        [AdmissionPolicy::Fifo, AdmissionPolicy::Priority, AdmissionPolicy::Edf];

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fifo => "fifo",
            AdmissionPolicy::Priority => "priority",
            AdmissionPolicy::Edf => "edf",
        }
    }

    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "fifo" => Some(AdmissionPolicy::Fifo),
            "priority" => Some(AdmissionPolicy::Priority),
            "edf" => Some(AdmissionPolicy::Edf),
            _ => None,
        }
    }
}

/// One admission request for [`SessionQueue::admit_request`]: the §5.1
/// byte footprint plus the ordering inputs the non-FIFO policies key on.
#[derive(Debug, Clone, Copy)]
pub struct AdmitRequest {
    /// Planned peak arena footprint charged against the budget.
    pub bytes: u64,
    /// Priority class, 0 = most urgent ([`AdmissionPolicy::Priority`]).
    pub class: u8,
    /// How long the request is willing to wait in line. Doubles as the
    /// EDF deadline key and the budget of the predicted-wait shed check;
    /// `None` waits indefinitely (and sorts last under EDF).
    pub patience: Option<Duration>,
}

impl AdmitRequest {
    pub fn new(bytes: u64) -> AdmitRequest {
        AdmitRequest { bytes, class: DEFAULT_PRIORITY_CLASS, patience: None }
    }

    pub fn with_class(mut self, class: u8) -> AdmitRequest {
        self.class = class;
        self
    }

    pub fn with_patience(mut self, patience: Duration) -> AdmitRequest {
        self.patience = Some(patience);
        self
    }
}

/// Default priority class for requests that don't specify one (the legacy
/// `admit`/`admit_timeout` paths): one step below most-urgent, so real
/// interactive traffic can outrank it and aging can still promote past it.
pub const DEFAULT_PRIORITY_CLASS: u8 = 1;

/// Blocked-grant history needed before the predicted-wait shed check
/// trusts its pace estimate.
const PREDICT_MIN_GRANTS: u64 = 4;

/// Effective priority class of a waiter that has waited `waited_us` under
/// an aging quantum of `quantum_us`: the class improves one step per full
/// quantum waited and **saturates at 0** — a class-0 (or long-aged)
/// request stays at 0 forever instead of wrapping, which a plain `-`
/// would do (panic in debug builds, a giant key in release, starving the
/// oldest waiter). Pinned by `aged_class_saturates_at_zero` below.
fn effective_class(class: u8, waited_us: u64, quantum_us: u64) -> u64 {
    let aged = waited_us / quantum_us.max(1);
    (class as u64).saturating_sub(aged)
}

/// §5.1 admission control: a byte budget over the *planned peak arena
/// footprints* of in-flight sessions ([`crate::graph::memory::plan`]).
/// [`admit`](SessionQueue::admit) blocks until the session fits; a session
/// larger than the whole budget is admitted only when nothing else is in
/// flight (serial degradation instead of deadlock).
///
/// Blocked requests are served in **policy order** ([`AdmissionPolicy`]):
/// FIFO tickets by default (strict arrival order, bit-compatible with the
/// original FIFO-only queue), priority classes with aging, or EDF over
/// per-request patience deadlines. Whatever the order, only the policy's
/// head-of-line request takes freed budget — no bypass — which is what
/// keeps the no-starvation guarantees stated on [`AdmissionPolicy`]
/// (the price is that requests behind a blocked head wait with it, the
/// usual fairness/throughput trade; [`try_admit`](SessionQueue::try_admit)
/// refuses to jump an existing queue).
///
/// **Overload shedding** ([`SessionQueue::admit_request`]): a bounded
/// queue rejects early with a structured [`ShedReason`] — at arrival when
/// the depth cap is hit or the grant-pace estimator predicts the wait
/// will outlive the request's patience, or in line when the patience
/// (clamped by [`with_wait_cap`](SessionQueue::with_wait_cap)) expires.
/// Fast structured rejection instead of latency collapse.
#[derive(Debug)]
pub struct SessionQueue {
    budget_bytes: u64,
    policy: AdmissionPolicy,
    /// At most this many requests may wait in line; arrivals beyond it
    /// shed immediately ([`ShedReason::QueueFull`]). `None` = unbounded.
    depth_cap: Option<u64>,
    /// Upper bound on any bounded request's time in line; clamps the
    /// per-request patience. `None` = patience only.
    wait_cap: Option<Duration>,
    /// Enables the [`ShedReason::PredictedLate`] arrival check.
    predict: bool,
    /// A waiting request's effective priority class improves by one every
    /// full quantum it has waited (anti-starvation aging).
    age_quantum: Duration,
    /// Clock epoch for the µs keys (EDF deadlines, aging, grant pacing).
    epoch: Instant,
    /// Requests shed for any [`ShedReason`] over the queue's lifetime.
    sheds: AtomicU64,
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// A blocked non-FIFO request: everything [`SessionQueue::policy_head`]
/// needs to rank it, keyed by arrival ticket in `QueueState::waiters`.
#[derive(Debug, Clone, Copy)]
struct Waiter {
    class: u8,
    /// EDF key: absolute patience deadline, µs since the queue epoch
    /// (`u64::MAX` when the request has no patience).
    deadline_us: u64,
    /// Aging base, µs since the queue epoch.
    enqueued_us: u64,
}

#[derive(Debug, Default)]
struct QueueState {
    in_use: u64,
    /// Next ticket to hand out to a blocking `admit`.
    next_ticket: u64,
    /// FIFO only: ticket currently at the head of the line
    /// (== `next_ticket` when nobody is waiting).
    head: u64,
    /// FIFO only: tickets whose holder gave up
    /// ([`SessionQueue::admit_timeout`]) before reaching the head;
    /// [`bump_head`] skips over them so an abandoned place in line never
    /// wedges the queue. Bounded by the number of concurrently blocked
    /// requests: every entry is < `next_ticket`, > `head`, and is removed
    /// the moment the head reaches it (see
    /// `prop_abandoned_tickets_always_drain` below).
    abandoned: BTreeSet<u64>,
    /// Priority/EDF only: blocked requests by arrival ticket; the policy
    /// head is the minimum effective key over this map. A waiter that
    /// gives up removes itself directly — the non-FIFO analogue of the
    /// abandoned set, with the same cannot-grow-unbounded property.
    waiters: BTreeMap<u64, Waiter>,
    /// Grant pacing for the predicted-wait shed check: EWMA of the gap
    /// between consecutive grants to *blocked* requests.
    last_grant_us: Option<u64>,
    grant_gap_ewma_us: f64,
    blocked_grants: u64,
}

/// Advance the head ticket past any abandoned ones.
fn bump_head(state: &mut QueueState) {
    state.head += 1;
    while state.abandoned.remove(&state.head) {
        state.head += 1;
    }
}

impl SessionQueue {
    pub fn new(budget_bytes: u64) -> SessionQueue {
        SessionQueue {
            budget_bytes,
            policy: AdmissionPolicy::Fifo,
            depth_cap: None,
            wait_cap: None,
            predict: false,
            age_quantum: Duration::from_millis(5),
            epoch: Instant::now(),
            sheds: AtomicU64::new(0),
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }

    /// Order blocked requests by `policy` instead of FIFO tickets.
    pub fn with_policy(mut self, policy: AdmissionPolicy) -> SessionQueue {
        self.policy = policy;
        self
    }

    /// Bound the line: arrivals that would be the `cap + 1`-th waiter shed
    /// immediately with [`ShedReason::QueueFull`].
    pub fn with_depth_cap(mut self, cap: u64) -> SessionQueue {
        self.depth_cap = Some(cap);
        self
    }

    /// Cap any bounded request's time in line, whatever its own patience.
    pub fn with_wait_cap(mut self, cap: Duration) -> SessionQueue {
        self.wait_cap = Some(cap);
        self
    }

    /// Shed at arrival when the observed grant pace predicts the wait
    /// would outlive the request's patience ([`ShedReason::PredictedLate`]).
    pub fn with_wait_prediction(mut self) -> SessionQueue {
        self.predict = true;
        self
    }

    /// Priority-aging quantum: a waiter's effective class improves by one
    /// per full quantum waited ([`AdmissionPolicy::Priority`]).
    pub fn with_priority_aging(mut self, quantum: Duration) -> SessionQueue {
        assert!(quantum > Duration::ZERO, "aging quantum must be positive");
        self.age_quantum = quantum;
        self
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Bytes currently admitted.
    pub fn in_use(&self) -> u64 {
        self.state.lock().unwrap().in_use
    }

    /// Requests currently blocked in [`admit`](Self::admit) /
    /// [`admit_timeout`](Self::admit_timeout) /
    /// [`admit_request`](Self::admit_request).
    pub fn waiting(&self) -> u64 {
        self.waiting_locked(&self.state.lock().unwrap())
    }

    /// Requests shed for any [`ShedReason`] over the queue's lifetime.
    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::Relaxed)
    }

    fn waiting_locked(&self, state: &QueueState) -> u64 {
        match self.policy {
            AdmissionPolicy::Fifo => state.next_ticket - state.head - state.abandoned.len() as u64,
            _ => state.waiters.len() as u64,
        }
    }

    #[cfg(test)]
    fn abandoned_len(&self) -> usize {
        self.state.lock().unwrap().abandoned.len()
    }

    fn fits(&self, used: u64, bytes: u64) -> bool {
        used == 0 || used.saturating_add(bytes) <= self.budget_bytes
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Grant pacing sample: a blocked request just received the budget.
    fn note_blocked_grant(&self, state: &mut QueueState) {
        let now = self.now_us();
        if let Some(prev) = state.last_grant_us {
            let gap = now.saturating_sub(prev) as f64;
            state.grant_gap_ewma_us = if state.blocked_grants <= 1 {
                gap
            } else {
                0.2 * gap + 0.8 * state.grant_gap_ewma_us
            };
        }
        state.last_grant_us = Some(now);
        state.blocked_grants += 1;
    }

    /// Block until `bytes` fit under the budget (policy order among
    /// blocked requests); the permit returns the bytes on drop
    /// ([`AdmissionPermit`] is RAII, so a caller that errors between
    /// admission and run cannot leak budget).
    pub fn admit(&self, bytes: u64) -> AdmissionPermit<'_> {
        self.admit_shaped(AdmitRequest::new(bytes), false)
            .unwrap_or_else(|r| unreachable!("untimed admit cannot shed ({r})"))
    }

    /// [`admit`](Self::admit) with a patience bound: returns `None` —
    /// abandoning the place in line without stranding the requests behind
    /// it — if the budget has not freed within `patience`. This is the
    /// original shedding primitive; [`admit_request`](Self::admit_request)
    /// is the bounded-queue superset that also rejects at arrival.
    pub fn admit_timeout(&self, bytes: u64, patience: Duration) -> Option<AdmissionPermit<'_>> {
        self.admit_shaped(AdmitRequest::new(bytes).with_patience(patience), false).ok()
    }

    /// The full overload-aware admission path: policy-ordered wait, plus
    /// the bounded-queue early rejections (depth cap, predicted-late) and
    /// the wait cap. Every rejection is a structured [`ShedReason`].
    pub fn admit_request(&self, req: AdmitRequest) -> Result<AdmissionPermit<'_>, ShedReason> {
        self.admit_shaped(req, true)
    }

    fn admit_shaped(
        &self,
        req: AdmitRequest,
        bounded: bool,
    ) -> Result<AdmissionPermit<'_>, ShedReason> {
        let enqueued_us = self.now_us();
        // the EDF key uses the request's own patience (its SLO); the wait
        // cap only bounds how long it may actually stand in line
        let deadline_key = req
            .patience
            .map_or(u64::MAX, |p| enqueued_us.saturating_add(p.as_micros() as u64));
        let patience = match (bounded, self.wait_cap) {
            (true, Some(cap)) => Some(req.patience.map_or(cap, |p| p.min(cap))),
            _ => req.patience,
        };
        let give_up_at = patience.map(|p| Instant::now() + p);

        let mut state = self.state.lock().unwrap();
        let immediate = match self.policy {
            AdmissionPolicy::Fifo => state.head == state.next_ticket,
            _ => state.waiters.is_empty(),
        } && self.fits(state.in_use, req.bytes);
        if bounded && !immediate {
            if let Some(cap) = self.depth_cap {
                if self.waiting_locked(&state) >= cap {
                    drop(state);
                    self.sheds.fetch_add(1, Ordering::Relaxed);
                    return Err(ShedReason::QueueFull);
                }
            }
            if self.predict && state.blocked_grants >= PREDICT_MIN_GRANTS {
                if let Some(p) = patience {
                    let depth = self.waiting_locked(&state) + 1;
                    // the EWMA only updates when grants happen, so during a
                    // no-grant stall it goes stale (low) exactly when the
                    // line is most hopeless — floor the per-grant pace with
                    // the observed time since the last grant, which is a
                    // lower bound on the *next* gap
                    let stall_us = state
                        .last_grant_us
                        .map_or(0.0, |g| enqueued_us.saturating_sub(g) as f64);
                    let est_gap_us = state.grant_gap_ewma_us.max(stall_us);
                    let est_wait_us = depth as f64 * est_gap_us;
                    if est_wait_us > p.as_micros() as f64 {
                        drop(state);
                        self.sheds.fetch_add(1, Ordering::Relaxed);
                        return Err(ShedReason::PredictedLate);
                    }
                }
            }
        }
        let ticket = state.next_ticket;
        state.next_ticket += 1;
        if self.policy != AdmissionPolicy::Fifo {
            state.waiters.insert(
                ticket,
                Waiter { class: req.class, deadline_us: deadline_key, enqueued_us },
            );
        }
        let mut waited = false;
        loop {
            let at_head = match self.policy {
                AdmissionPolicy::Fifo => state.head == ticket,
                _ => self.policy_head(&state) == Some(ticket),
            };
            if at_head && self.fits(state.in_use, req.bytes) {
                match self.policy {
                    AdmissionPolicy::Fifo => bump_head(&mut state),
                    _ => {
                        state.waiters.remove(&ticket);
                    }
                }
                if waited {
                    self.note_blocked_grant(&mut state);
                }
                state.in_use += req.bytes;
                drop(state);
                // the next request in policy order may already fit — let
                // it re-check
                self.cv.notify_all();
                return Ok(AdmissionPermit { queue: self, bytes: req.bytes });
            }
            match give_up_at {
                None => {
                    state = self.cv.wait(state).unwrap();
                    waited = true;
                }
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        match self.policy {
                            AdmissionPolicy::Fifo => {
                                if state.head == ticket {
                                    bump_head(&mut state);
                                } else {
                                    state.abandoned.insert(ticket);
                                }
                            }
                            _ => {
                                state.waiters.remove(&ticket);
                            }
                        }
                        drop(state);
                        self.sheds.fetch_add(1, Ordering::Relaxed);
                        // whoever was ranked behind the abandoned request
                        // may now hold the head — let it re-check
                        self.cv.notify_all();
                        return Err(ShedReason::AdmissionTimeout);
                    }
                    state = self.cv.wait_timeout(state, d - now).unwrap().0;
                    waited = true;
                }
            }
        }
    }

    /// The blocked request the policy currently ranks first. Scans the
    /// waiter map (bounded by the depth cap / concurrent-client count) so
    /// priority aging is evaluated from enqueue times at selection — no
    /// stale-key races between waiters re-keying themselves.
    fn policy_head(&self, state: &QueueState) -> Option<u64> {
        let now_us = self.now_us();
        let quantum_us = (self.age_quantum.as_micros() as u64).max(1);
        state
            .waiters
            .iter()
            .min_by_key(|(ticket, w)| {
                let key = match self.policy {
                    AdmissionPolicy::Priority => {
                        effective_class(w.class, now_us.saturating_sub(w.enqueued_us), quantum_us)
                    }
                    AdmissionPolicy::Edf => w.deadline_us,
                    AdmissionPolicy::Fifo => unreachable!("FIFO orders by head ticket"),
                };
                (key, **ticket)
            })
            .map(|(ticket, _)| *ticket)
    }

    /// Non-blocking [`admit`](Self::admit): succeeds only when the bytes
    /// fit *and* no other request is queued (no queue jumping, whatever
    /// the policy).
    pub fn try_admit(&self, bytes: u64) -> Option<AdmissionPermit<'_>> {
        let mut state = self.state.lock().unwrap();
        let nobody_waiting = match self.policy {
            AdmissionPolicy::Fifo => state.head == state.next_ticket,
            _ => state.waiters.is_empty(),
        };
        if nobody_waiting && self.fits(state.in_use, bytes) {
            state.in_use += bytes;
            Some(AdmissionPermit { queue: self, bytes })
        } else {
            None
        }
    }
}

/// An admitted session's claim on the memory budget; released on drop.
#[derive(Debug)]
pub struct AdmissionPermit<'a> {
    queue: &'a SessionQueue,
    bytes: u64,
}

impl AdmissionPermit<'_> {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        let mut state = self.queue.state.lock().unwrap();
        state.in_use -= self.bytes;
        drop(state);
        self.queue.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build as mlp, MlpConfig};
    use std::sync::atomic::AtomicU32;

    fn unit_levels(g: &Graph) -> Vec<f64> {
        vec![1.0; g.len()]
    }

    #[test]
    fn one_session_runs_to_quiescence_in_both_modes() {
        let g = mlp(&MlpConfig::default());
        for mode in DispatchMode::ALL {
            let counts: Vec<AtomicU32> = (0..g.len()).map(|_| AtomicU32::new(0)).collect();
            let work = |n: NodeId| {
                counts[n as usize].fetch_add(1, Ordering::SeqCst);
            };
            let totals = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
                let report =
                    fleet.submit(&g, unit_levels(&g), &work).wait().expect("healthy session");
                assert_eq!(report.records.len(), g.len(), "{}", mode.name());
                assert_eq!(report.dispatches, g.len() as u64, "{}", mode.name());
                fleet.shutdown().expect("clean fleet")
            });
            for (v, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::SeqCst), 1, "{}: node {v}", mode.name());
            }
            assert_eq!(totals.dispatches, g.len() as u64, "{}", mode.name());
            assert_eq!(totals.sessions_completed, 1, "{}", mode.name());
        }
    }

    #[test]
    fn tiny_deques_spill_without_losing_ops() {
        // a 1 → 32 → 1 fan through capacity-2 deques: nearly every
        // successor push overflows into the owner-local spill, and the
        // session must still run every op exactly once
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let src = b.add("src", OpKind::Scalar);
        let mids: Vec<NodeId> = (0..32)
            .map(|i| {
                let m = b.add(format!("m{i}"), OpKind::Scalar);
                b.depend(src, m);
                m
            })
            .collect();
        b.add_after("sink", OpKind::Scalar, &mids);
        let g = b.build().unwrap();
        let counts: Vec<AtomicU32> = (0..g.len()).map(|_| AtomicU32::new(0)).collect();
        let work = |n: NodeId| {
            counts[n as usize].fetch_add(1, Ordering::SeqCst);
        };
        std::thread::scope(|scope| {
            let config = FleetConfig { deque_capacity: 2, ..FleetConfig::new(4) };
            let fleet = Fleet::new(scope, config);
            let report = fleet.submit(&g, unit_levels(&g), &work).wait().expect("healthy session");
            assert_eq!(report.records.len(), g.len());
            fleet.shutdown().expect("clean fleet");
        });
        for c in &counts {
            assert_eq!(c.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn session_queue_blocks_until_budget_frees() {
        let q = SessionQueue::new(1000);
        let a = q.admit(800);
        assert_eq!(q.in_use(), 800);
        assert!(q.try_admit(300).is_none(), "over budget must not admit");
        let b = q.try_admit(200).expect("fits alongside");
        drop(b);
        std::thread::scope(|s| {
            let (tx, rx) = std::sync::mpsc::channel();
            s.spawn(|| {
                let permit = q.admit(300); // blocks until `a` drops
                tx.send(q.in_use()).unwrap();
                drop(permit);
            });
            // the admit above must still be blocked
            assert!(
                rx.recv_timeout(Duration::from_millis(100)).is_err(),
                "over-budget session must wait for the budget to free"
            );
            drop(a);
            let seen = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(seen, 300);
        });
        assert_eq!(q.in_use(), 0);
    }

    #[test]
    fn admission_is_fifo_small_sessions_cannot_starve_a_large_one() {
        let q = SessionQueue::new(100);
        let small = q.admit(60);
        std::thread::scope(|s| {
            let (tx, rx) = std::sync::mpsc::channel();
            let q = &q;
            s.spawn(move || {
                let big = q.admit(80); // blocks behind `small`
                tx.send(q.in_use()).unwrap();
                drop(big);
            });
            // wait until the large request holds the head ticket
            while q.waiting() == 0 {
                std::thread::yield_now();
            }
            // a newcomer that *would* fit must not jump the queue
            assert!(
                q.try_admit(10).is_none(),
                "try_admit jumped ahead of a queued large request"
            );
            drop(small);
            let seen = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(seen, 80, "the queued large request must be admitted next");
        });
        assert_eq!(q.in_use(), 0);
        assert_eq!(q.waiting(), 0);
    }

    #[test]
    fn oversized_session_admitted_only_alone() {
        let q = SessionQueue::new(100);
        let small = q.admit(60);
        assert!(q.try_admit(5000).is_none(), "oversized must wait while others run");
        drop(small);
        let big = q.try_admit(5000).expect("oversized runs alone");
        assert!(q.try_admit(1).is_none(), "nothing joins an oversized session");
        drop(big);
    }

    #[test]
    #[should_panic(expected = "one domain per executor")]
    fn mismatched_numa_map_rejected_at_fleet_construction() {
        std::thread::scope(|scope| {
            let config = FleetConfig {
                numa: Some(DomainMap::new(vec![0, 1], 0)),
                ..FleetConfig::new(4)
            };
            let _ = Fleet::new(scope, config);
        });
    }

    fn chain(n: usize) -> Graph {
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let mut prev = b.add("n0", OpKind::Scalar);
        for i in 1..n {
            let cur = b.add(format!("n{i}"), OpKind::Scalar);
            b.depend(prev, cur);
            prev = cur;
        }
        b.build().unwrap()
    }

    #[test]
    fn op_panic_confined_to_its_session_in_both_modes() {
        let healthy_g = mlp(&MlpConfig::default());
        let faulty_g = chain(6);
        for mode in DispatchMode::ALL {
            let counts: Vec<AtomicU32> =
                (0..healthy_g.len()).map(|_| AtomicU32::new(0)).collect();
            let healthy_work = |n: NodeId| {
                counts[n as usize].fetch_add(1, Ordering::SeqCst);
            };
            let faulty_work = |n: NodeId| {
                if n == 3 {
                    panic!("injected fault at node 3");
                }
            };
            let err = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
                let faulty = fleet.submit(&faulty_g, unit_levels(&faulty_g), &faulty_work);
                let healthy = fleet.submit(&healthy_g, unit_levels(&healthy_g), &healthy_work);
                let err = faulty.wait().expect_err("node 3 panics");
                assert_eq!(
                    err,
                    SessionError::OpPanicked {
                        node: 3,
                        payload: "injected fault at node 3".into()
                    },
                    "{}",
                    mode.name()
                );
                let report = healthy.wait().expect("healthy session unaffected by the fault");
                assert_eq!(report.records.len(), healthy_g.len(), "{}", mode.name());
                // the fleet keeps serving after the fault
                fleet
                    .submit(&healthy_g, unit_levels(&healthy_g), &healthy_work)
                    .wait()
                    .expect("post-fault session completes");
                fleet.shutdown().expect_err("a failed session must surface at shutdown")
            });
            assert_eq!(err.sessions_failed, 1, "{}", mode.name());
            assert!(err.panicked_threads.is_empty(), "{}: op panics are caught", mode.name());
            assert_eq!(err.totals.sessions_completed, 2, "{}", mode.name());
            for (v, c) in counts.iter().enumerate() {
                assert_eq!(
                    c.load(Ordering::SeqCst),
                    2,
                    "{}: node {v} exactly once per healthy session",
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn moldable_session_runs_exactly_once_and_forms_gangs_in_both_modes() {
        let g = chain(16);
        let widths: Vec<u8> = vec![3; g.len()];
        for mode in DispatchMode::ALL {
            let rank0_hits: Vec<AtomicU32> = (0..g.len()).map(|_| AtomicU32::new(0)).collect();
            let max_width = AtomicU32::new(0);
            let totals = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(4).with_dispatch(mode));
                let rank0_hits = &rank0_hits;
                let max_width = &max_width;
                let report = fleet
                    .submit_moldable(
                        &g,
                        unit_levels(&g),
                        widths.clone(),
                        Arc::new(move |n: NodeId, rank: u32, width: u32| {
                            assert!(rank < width, "rank {rank} outside a width-{width} gang");
                            if rank == 0 {
                                rank0_hits[n as usize].fetch_add(1, Ordering::SeqCst);
                            }
                            max_width.fetch_max(width, Ordering::SeqCst);
                            // a small op body still leaves recruits time
                            // to cycle back before the next formation
                            std::thread::sleep(Duration::from_micros(200));
                        }),
                        None,
                    )
                    .wait()
                    .expect("moldable session quiesces");
                assert_eq!(report.records.len(), g.len(), "{}: one record per op", mode.name());
                fleet.shutdown().expect("clean shutdown")
            });
            for (v, c) in rank0_hits.iter().enumerate() {
                assert_eq!(
                    c.load(Ordering::SeqCst),
                    1,
                    "{}: node {v} led exactly one gang",
                    mode.name()
                );
            }
            // 16 wide ops on an otherwise idle 4-executor fleet: some
            // formation must have closed above width 1
            assert!(totals.gangs_formed > 0, "{}: no gang ever formed", mode.name());
            assert!(totals.gang_recruits >= totals.gangs_formed, "{}", mode.name());
            assert!(max_width.load(Ordering::SeqCst) > 1, "{}", mode.name());
            assert!(max_width.load(Ordering::SeqCst) <= 3, "{}: width is a cap", mode.name());
        }
    }

    #[test]
    fn gang_member_panic_confined_to_its_session_in_both_modes() {
        let faulty_g = chain(8);
        let healthy_g = mlp(&MlpConfig::default());
        let widths: Vec<u8> = vec![4; faulty_g.len()];
        for mode in DispatchMode::ALL {
            let widest = AtomicU32::new(0);
            let healthy_work = |_n: NodeId| {};
            let err = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(4).with_dispatch(mode));
                let widest = &widest;
                // the highest-ranked seat panics: a recruited member when
                // a gang formed, the leader itself when it stayed solo
                let faulty = fleet.submit_moldable(
                    &faulty_g,
                    unit_levels(&faulty_g),
                    widths.clone(),
                    Arc::new(move |n: NodeId, rank: u32, width: u32| {
                        widest.fetch_max(width, Ordering::SeqCst);
                        if n == 5 && rank == width - 1 {
                            panic!("injected gang fault at node 5");
                        }
                        std::thread::sleep(Duration::from_micros(200));
                    }),
                    None,
                );
                let err = faulty.wait().expect_err("the widest seat at node 5 panics");
                assert_eq!(
                    err,
                    SessionError::OpPanicked {
                        node: 5,
                        payload: "injected gang fault at node 5".into()
                    },
                    "{}",
                    mode.name()
                );
                // gang members released and the fleet keeps serving
                fleet
                    .submit(&healthy_g, unit_levels(&healthy_g), &healthy_work)
                    .wait()
                    .expect("post-fault session completes");
                fleet.shutdown().expect_err("the gang fault must surface at shutdown")
            });
            assert_eq!(err.sessions_failed, 1, "{}", mode.name());
            assert!(err.panicked_threads.is_empty(), "{}: gang panics are caught", mode.name());
            assert!(
                err.totals.gangs_formed > 0,
                "{}: the fault run must actually have ganged",
                mode.name()
            );
        }
    }

    #[test]
    fn width_one_moldable_session_never_forms_gangs() {
        let g = chain(6);
        for mode in DispatchMode::ALL {
            let hits = AtomicU32::new(0);
            let totals = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
                let hits = &hits;
                let report = fleet
                    .submit_moldable(
                        &g,
                        unit_levels(&g),
                        vec![1u8; g.len()],
                        Arc::new(move |_n: NodeId, rank: u32, width: u32| {
                            assert_eq!((rank, width), (0, 1), "width-1 ops never gang");
                            hits.fetch_add(1, Ordering::SeqCst);
                        }),
                        None,
                    )
                    .wait()
                    .expect("width-1 moldable session quiesces");
                assert_eq!(report.records.len(), g.len(), "{}", mode.name());
                fleet.shutdown().expect("clean shutdown")
            });
            assert_eq!(hits.load(Ordering::SeqCst), g.len() as u32, "{}", mode.name());
            assert_eq!(totals.gangs_formed, 0, "{}", mode.name());
            assert_eq!(totals.gang_recruits, 0, "{}", mode.name());
        }
    }

    #[test]
    fn cancel_terminates_session_with_structured_error() {
        let g = chain(8);
        for mode in DispatchMode::ALL {
            let release = AtomicBool::new(false);
            let executed = AtomicU32::new(0);
            let work = |n: NodeId| {
                if n == 0 {
                    while !release.load(Ordering::Acquire) {
                        std::thread::yield_now();
                    }
                }
                executed.fetch_add(1, Ordering::SeqCst);
            };
            std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(2).with_dispatch(mode));
                let handle = fleet.submit(&g, unit_levels(&g), &work);
                // the request lands while node 0 blocks (or before any
                // pop at all), so some later pop must observe it
                handle.cancel();
                release.store(true, Ordering::Release);
                let err = handle.wait().expect_err("cancelled session");
                assert_eq!(err, SessionError::Cancelled, "{}", mode.name());
                assert!(executed.load(Ordering::SeqCst) <= 1, "{}", mode.name());
                fleet.shutdown().expect("cancel is not a fleet fault");
            });
        }
    }

    #[test]
    fn deadline_miss_reports_deadline_exceeded() {
        let g = chain(4);
        for mode in DispatchMode::ALL {
            let work = |n: NodeId| {
                if n == 0 {
                    std::thread::sleep(Duration::from_millis(25));
                }
            };
            std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(2).with_dispatch(mode));
                let handle =
                    fleet.submit_with_deadline(&g, unit_levels(&g), &work, Duration::from_millis(1));
                let err = handle.wait().expect_err("deadline passes during node 0");
                assert_eq!(err, SessionError::DeadlineExceeded, "{}", mode.name());
                fleet.shutdown().expect("a deadline miss is not a fleet fault");
            });
        }
    }

    #[test]
    fn watchdog_fails_stalled_session_instead_of_hanging() {
        let g = chain(2);
        for mode in DispatchMode::ALL {
            let release = AtomicBool::new(false);
            let work = |n: NodeId| {
                if n == 0 {
                    while !release.load(Ordering::Acquire) {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            };
            std::thread::scope(|scope| {
                let fleet = Fleet::new(
                    scope,
                    FleetConfig::new(2)
                        .with_dispatch(mode)
                        .with_watchdog(Duration::from_millis(50)),
                );
                let handle = fleet.submit(&g, unit_levels(&g), &work);
                let err = handle.wait().expect_err("watchdog unwedges the waiter");
                assert_eq!(err, SessionError::Stalled, "{}", mode.name());
                // unpin the executor so the fleet can join
                release.store(true, Ordering::Release);
                let err = fleet.shutdown().expect_err("a stalled session is a fault");
                assert_eq!(err.sessions_failed, 1, "{}", mode.name());
                assert!(err.panicked_threads.is_empty(), "{}", mode.name());
            });
        }
    }

    #[test]
    fn slot_reuse_after_fault_never_leaks_entries_across_sessions() {
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        // wide fan: one source readies 32 mids at once; mid `1` panics,
        // stranding up to 31 queued entries of the dying session
        let mut b = GraphBuilder::new();
        let src = b.add("src", OpKind::Scalar);
        let mids: Vec<NodeId> = (0..32)
            .map(|i| {
                let m = b.add(format!("m{i}"), OpKind::Scalar);
                b.depend(src, m);
                m
            })
            .collect();
        b.add_after("sink", OpKind::Scalar, &mids);
        let big = b.build().unwrap();
        let small = chain(2);
        for mode in DispatchMode::ALL {
            let faulty_work = |n: NodeId| {
                if n == 1 {
                    panic!("fault in the fan");
                }
            };
            let small_hits = AtomicU32::new(0);
            let small_work = |n: NodeId| {
                assert!((n as usize) < small.len(), "entry leaked across sessions");
                small_hits.fetch_add(1, Ordering::SeqCst);
            };
            std::thread::scope(|scope| {
                let config =
                    FleetConfig { max_sessions: 1, ..FleetConfig::new(4) }.with_dispatch(mode);
                let fleet = Fleet::new(scope, config);
                for round in 0..4 {
                    let err = fleet
                        .submit(&big, unit_levels(&big), &faulty_work)
                        .wait()
                        .expect_err("mid 1 panics");
                    assert!(
                        matches!(err, SessionError::OpPanicked { node: 1, .. }),
                        "{}: {err:?}",
                        mode.name()
                    );
                    // with one slot, this submit reuses slot 0 — which the
                    // count-gated release hands out only after every stale
                    // entry of the faulted session drained; a leaked entry
                    // would run small_work with a node ≥ small.len()
                    let report = fleet
                        .submit(&small, unit_levels(&small), &small_work)
                        .wait()
                        .expect("reused slot runs the right session");
                    assert_eq!(report.records.len(), small.len(), "{} round {round}", mode.name());
                }
                let err = fleet.shutdown().expect_err("faults recorded");
                assert_eq!(err.sessions_failed, 4, "{}", mode.name());
            });
            assert_eq!(small_hits.load(Ordering::SeqCst), 8, "{}", mode.name());
        }
    }

    #[test]
    fn admission_permit_released_on_drop_even_across_a_panic() {
        let q = SessionQueue::new(100);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _permit = q.admit(60);
            panic!("client errors between admit and run");
        }));
        assert!(result.is_err());
        assert_eq!(q.in_use(), 0, "the RAII permit must release on unwind");
        assert!(q.try_admit(100).is_some(), "full budget available again");
    }

    #[test]
    fn abandoned_ticket_does_not_wedge_the_queue() {
        let q = SessionQueue::new(100);
        let holder = q.admit(80);
        // times out behind the holder, abandoning its ticket
        assert!(q.admit_timeout(50, Duration::from_millis(20)).is_none());
        assert_eq!(q.waiting(), 0, "an abandoned ticket is not waiting");
        drop(holder);
        assert!(q.try_admit(100).is_some(), "abandoned ticket must not block the head");
        assert_eq!(q.in_use(), 0);
    }

    #[test]
    fn ticket_abandoned_behind_a_blocked_head_is_skipped() {
        let q = SessionQueue::new(100);
        let holder = q.admit(90);
        std::thread::scope(|s| {
            let q = &q;
            let (tx, rx) = std::sync::mpsc::channel();
            s.spawn(move || {
                let head = q.admit(70); // blocks behind `holder` at the head
                tx.send(q.in_use()).unwrap();
                drop(head);
            });
            while q.waiting() == 0 {
                std::thread::yield_now();
            }
            // this ticket gives up while the 70-byte request heads the line
            assert!(q.admit_timeout(10, Duration::from_millis(20)).is_none());
            drop(holder);
            let seen = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(seen, 70);
        });
        // the abandoned ticket was skipped over, not left wedging the head
        assert_eq!(q.waiting(), 0);
        assert!(q.try_admit(100).is_some());
    }

    /// Run `n` blocked full-budget requests against `q` while `setup`
    /// enqueues them in a fixed order, then return the order the queue
    /// granted them in. Each waiter takes the whole budget, so grants are
    /// strictly serialized and the observed order is exactly the policy's.
    fn grant_order(q: &SessionQueue, reqs: &[(&'static str, AdmitRequest)], gap: Duration) -> Vec<&'static str> {
        let holder = q.admit(q.budget_bytes());
        let order = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for (tag, req) in reqs {
                let order = &order;
                let q2 = &*q;
                let before = q2.waiting();
                s.spawn(move || {
                    let permit = q2.admit_request(*req).expect("spec waiters never shed");
                    order.lock().unwrap().push(*tag);
                    drop(permit);
                });
                // enqueue strictly in `reqs` order
                while q2.waiting() == before {
                    std::thread::yield_now();
                }
                std::thread::sleep(gap);
            }
            drop(holder);
        });
        order.into_inner().unwrap()
    }

    #[test]
    fn priority_admission_serves_urgent_classes_first() {
        // aging effectively off: only the classes order the line
        let q = SessionQueue::new(100)
            .with_policy(AdmissionPolicy::Priority)
            .with_priority_aging(Duration::from_secs(3600));
        let reqs = [
            ("bulk", AdmitRequest::new(100).with_class(3)),
            ("normal", AdmitRequest::new(100).with_class(1)),
            ("urgent", AdmitRequest::new(100).with_class(0)),
        ];
        assert_eq!(grant_order(&q, &reqs, Duration::ZERO), vec!["urgent", "normal", "bulk"]);
        assert_eq!(q.in_use(), 0);
        assert_eq!(q.waiting(), 0);
    }

    #[test]
    fn priority_aging_promotes_a_starved_low_class_waiter() {
        // anti-starvation spec: with a 1ms quantum, a class-3 request that
        // has waited ≥ 50ms holds effective class 0 with the older ticket,
        // so it beats a freshly arrived class-0 request
        let q = SessionQueue::new(100)
            .with_policy(AdmissionPolicy::Priority)
            .with_priority_aging(Duration::from_millis(1));
        let reqs = [
            ("aged-bulk", AdmitRequest::new(100).with_class(3)),
            ("fresh-urgent", AdmitRequest::new(100).with_class(0)),
        ];
        assert_eq!(
            grant_order(&q, &reqs, Duration::from_millis(50)),
            vec!["aged-bulk", "fresh-urgent"]
        );
    }

    #[test]
    fn edf_admission_serves_earliest_deadline_first() {
        let q = SessionQueue::new(100).with_policy(AdmissionPolicy::Edf);
        let reqs = [
            ("lazy", AdmitRequest::new(100).with_patience(Duration::from_secs(30))),
            ("patient", AdmitRequest::new(100).with_patience(Duration::from_secs(20))),
            ("tight", AdmitRequest::new(100).with_patience(Duration::from_secs(10))),
        ];
        // later arrivals with earlier deadlines overtake; no deadline is
        // anywhere near expiring, so ordering is purely the EDF key
        assert_eq!(grant_order(&q, &reqs, Duration::ZERO), vec!["tight", "patient", "lazy"]);
        assert_eq!(q.sheds(), 0, "nothing timed out in the EDF spec run");
    }

    #[test]
    fn depth_cap_sheds_arrivals_beyond_the_bound() {
        let q = SessionQueue::new(100).with_depth_cap(1);
        let holder = q.admit(100);
        std::thread::scope(|s| {
            let q = &q;
            s.spawn(move || {
                // the one allowed waiter
                let p = q
                    .admit_request(AdmitRequest::new(100).with_patience(Duration::from_secs(30)))
                    .expect("within the depth bound");
                drop(p);
            });
            while q.waiting() == 0 {
                std::thread::yield_now();
            }
            // the second would-be waiter is rejected at arrival, fast
            let err = q
                .admit_request(AdmitRequest::new(10).with_patience(Duration::from_secs(30)))
                .expect_err("beyond the depth bound");
            assert_eq!(err, ShedReason::QueueFull);
            drop(holder);
        });
        assert_eq!(q.sheds(), 1);
        assert_eq!(q.waiting(), 0);
        assert_eq!(q.in_use(), 0);
    }

    #[test]
    fn wait_prediction_sheds_hopeless_arrivals() {
        let q = SessionQueue::new(100).with_wait_prediction();
        // history: five blocked grants paced ≥5ms apart, so the EWMA gap
        // is well above the hopeless request's 1µs patience
        for _ in 0..5 {
            let holder = q.admit(100);
            std::thread::scope(|s| {
                let q = &q;
                s.spawn(move || {
                    let p = q.admit_request(
                        AdmitRequest::new(100).with_patience(Duration::from_secs(30)),
                    );
                    drop(p.expect("history waiters are patient"));
                });
                while q.waiting() == 0 {
                    std::thread::yield_now();
                }
                std::thread::sleep(Duration::from_millis(5));
                drop(holder);
            });
        }
        let holder = q.admit(100);
        std::thread::scope(|s| {
            let q = &q;
            s.spawn(move || {
                let p = q.admit_request(
                    AdmitRequest::new(100).with_patience(Duration::from_secs(30)),
                );
                drop(p.expect("patient waiter"));
            });
            while q.waiting() == 0 {
                std::thread::yield_now();
            }
            // est. wait ≈ 2 × (≥5ms gap) ≫ 1µs patience → shed at arrival
            let err = q
                .admit_request(AdmitRequest::new(10).with_patience(Duration::from_micros(1)))
                .expect_err("predicted to miss its patience");
            assert_eq!(err, ShedReason::PredictedLate);
            drop(holder);
        });
        assert_eq!(q.waiting(), 0);
        assert_eq!(q.in_use(), 0);
    }

    /// Satellite regression (fails before the stall floor): the grant-gap
    /// EWMA only updates when grants happen, so after a long no-grant
    /// stall the stale low estimate made `PredictedLate` under-shed
    /// exactly when the queue was most hopeless — the arrival below would
    /// wait out its whole patience and time out instead of being rejected
    /// at arrival. The fix floors the per-grant pace estimate with the
    /// observed elapsed time since the last grant.
    #[test]
    fn wait_prediction_survives_a_grant_stall() {
        let q = SessionQueue::new(100).with_wait_prediction();
        // history: five blocked grants paced ~1ms apart → EWMA ≈ 1ms
        for _ in 0..5 {
            let holder = q.admit(100);
            std::thread::scope(|s| {
                let q = &q;
                s.spawn(move || {
                    let p = q.admit_request(
                        AdmitRequest::new(100).with_patience(Duration::from_secs(30)),
                    );
                    drop(p.expect("history waiters are patient"));
                });
                while q.waiting() == 0 {
                    std::thread::yield_now();
                }
                std::thread::sleep(Duration::from_millis(1));
                drop(holder);
            });
        }
        // stall: the holder stops granting for 60ms, far past the EWMA
        let holder = q.admit(100);
        std::thread::scope(|s| {
            let q = &q;
            s.spawn(move || {
                let p = q.admit_request(
                    AdmitRequest::new(100).with_patience(Duration::from_secs(30)),
                );
                drop(p.expect("patient waiter"));
            });
            while q.waiting() == 0 {
                std::thread::yield_now();
            }
            std::thread::sleep(Duration::from_millis(60));
            // depth 2 × floored gap (≥60ms stall) ≫ 30ms patience: shed at
            // arrival. Pre-fix the estimate stayed ≈ 2 × 1ms EWMA < 30ms,
            // so this request waited its patience out (AdmissionTimeout).
            let t0 = Instant::now();
            let err = q
                .admit_request(AdmitRequest::new(10).with_patience(Duration::from_millis(30)))
                .expect_err("a stalled queue must shed predictably-late arrivals");
            assert_eq!(err, ShedReason::PredictedLate);
            assert!(
                t0.elapsed() < Duration::from_millis(25),
                "predicted-late is an at-arrival rejection, not a timeout"
            );
            drop(holder);
        });
        assert_eq!(q.waiting(), 0);
        assert_eq!(q.in_use(), 0);
    }

    /// Satellite pin: the effective-class computation saturates at class
    /// 0 however long the wait — a class-0 waiter aged for many quanta
    /// must not wrap (debug-build panic / giant release key).
    #[test]
    fn aged_class_saturates_at_zero() {
        // class 0 aged 1000 quanta: a plain `-` would underflow here
        assert_eq!(effective_class(0, 1_000_000, 1_000), 0);
        assert_eq!(effective_class(2, 0, 1_000), 2);
        assert_eq!(effective_class(2, 2_000, 1_000), 0);
        assert_eq!(effective_class(2, u64::MAX, 1_000), 0);
        // a zero quantum is floored, never a divide-by-zero
        assert_eq!(effective_class(3, 10, 0), 0);
    }

    /// Satellite pin, end-to-end: a class-0 waiter aged ~50 quanta keeps
    /// the head against a fresh class-0 arrival (both saturate to
    /// effective class 0; the older ticket breaks the tie) — long waits
    /// neither wrap nor demote the oldest waiter.
    #[test]
    fn long_aged_class0_waiter_keeps_the_head() {
        let q = SessionQueue::new(100)
            .with_policy(AdmissionPolicy::Priority)
            .with_priority_aging(Duration::from_millis(1));
        let reqs = [
            ("old-urgent", AdmitRequest::new(100).with_class(0)),
            ("fresh-urgent", AdmitRequest::new(100).with_class(0)),
        ];
        assert_eq!(
            grant_order(&q, &reqs, Duration::from_millis(50)),
            vec!["old-urgent", "fresh-urgent"]
        );
        assert_eq!(q.waiting(), 0);
    }

    #[test]
    fn wait_cap_bounds_time_in_line() {
        let q = SessionQueue::new(100).with_wait_cap(Duration::from_millis(10));
        let holder = q.admit(100);
        let t0 = Instant::now();
        // a very patient request still gives up at the 10ms wait cap
        let err = q
            .admit_request(AdmitRequest::new(10).with_patience(Duration::from_secs(3600)))
            .expect_err("wait cap must bound the line");
        assert_eq!(err, ShedReason::AdmissionTimeout);
        assert!(t0.elapsed() < Duration::from_secs(60), "gave up in bounded time");
        drop(holder);
        assert_eq!(q.sheds(), 1);
    }

    /// Satellite regression: the `abandoned` ticket set cannot grow
    /// without bound during sustained shedding — `bump_head` drains every
    /// abandoned ticket at the head, so once all requests resolve the set
    /// is empty and the head has caught up to `next_ticket`. Property
    /// over interleaved admits / timeouts / releases.
    #[test]
    fn prop_abandoned_tickets_always_drain() {
        use crate::util::testkit::{check, UsizeRange, VecOf};
        // a case is the per-abandoner patience in ms (0–4ms each); the
        // vector length is how many abandoners churn behind the head
        let gen = VecOf { inner: UsizeRange(0, 4), min_len: 1, max_len: 12 };
        check("abandoned tickets drain", &gen, 15, |patiences| {
            let q = SessionQueue::new(100);
            let holder = q.admit(90);
            std::thread::scope(|s| {
                let q = &q;
                // two persistent waiters: the head-of-line request the
                // abandoners churn behind, plus one more behind them
                for _ in 0..2 {
                    s.spawn(move || {
                        let p = q.admit(50);
                        std::thread::sleep(Duration::from_micros(200));
                        drop(p);
                    });
                }
                while q.waiting() < 2 {
                    std::thread::yield_now();
                }
                for &ms in patiences {
                    s.spawn(move || {
                        // most of these time out behind the blocked head
                        // and park their tickets in `abandoned`
                        let _ = q.admit_timeout(30, Duration::from_millis(ms as u64));
                    });
                }
                // interleave the release with the timeout churn; release
                // the budget *before* judging the peak so a failing case
                // still lets the persistent waiters drain and join
                std::thread::sleep(Duration::from_millis(2));
                let peak = q.abandoned_len();
                drop(holder);
                if peak > patiences.len() {
                    return Err(format!(
                        "abandoned grew past the abandoner count: {peak} > {}",
                        patiences.len()
                    ));
                }
                Ok(())
            })?;
            // every thread has resolved: the head must have caught up and
            // drained every abandoned ticket on its way
            if q.abandoned_len() != 0 {
                return Err(format!("{} abandoned ticket(s) leaked", q.abandoned_len()));
            }
            if q.waiting() != 0 || q.in_use() != 0 {
                return Err(format!(
                    "queue not quiescent: waiting {} in_use {}",
                    q.waiting(),
                    q.in_use()
                ));
            }
            if q.try_admit(100).is_none() {
                return Err("head wedged after churn".into());
            }
            Ok(())
        });
    }

    #[test]
    fn shed_error_formats_with_its_reason() {
        let err = SessionError::Shed { reason: ShedReason::QueueFull };
        assert_eq!(err.to_string(), "request shed at admission: queue_full");
        assert_eq!(ShedReason::PredictedLate.name(), "predicted_late");
        for p in AdmissionPolicy::ALL {
            assert_eq!(AdmissionPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(AdmissionPolicy::parse("nope"), None);
    }
}
