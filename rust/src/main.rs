//! `graphi` binary entry point. All logic lives in the library; see
//! [`graphi::cli`].

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(graphi::cli::main(args));
}
