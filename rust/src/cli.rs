//! The `graphi` command-line interface.
//!
//! ```text
//! graphi run      [--config cfg.toml | --model lstm --size medium ...]
//! graphi profile  --model lstm --size medium
//! graphi autotune --model lstm --size medium [--force] [--compare]
//! graphi stats    --model pathnet --size large [--dot out.dot]
//! graphi trace    --model lstm --size small --executors 8 --threads 8 [--check FILE]
//! graphi bench    <fig2|fig3|fig5|fig6|table2|ablations|all> [--fast]
//! graphi serve    [--requests 200 --clients 4 --dispatch both --mix lstm=1,mlp=1,...]
//!                 [--trace-chrome t.json --telemetry-every-ms 500]
//! graphi train    [--steps 200] [--artifacts DIR]
//! ```

use crate::bail;
use crate::util::error::{Context, Error, Result};

use crate::coordinator::config::{EngineChoice, ExperimentConfig};
use crate::coordinator::driver::Driver;
use crate::coordinator::figures;
use crate::engine::policies::Policy;
use crate::engine::{
    Autotuner, DispatchMode, Engine, GraphiEngine, Profiler, SimEnv, Trace, WidthPlan,
};
use crate::graph::GraphStats;
use crate::models::{self, ModelKind, ModelSize};
use crate::runtime::artifacts::{tuning_path, tuning_path_for, MachineKey, TuningArtifact};
use crate::util::bench::{BenchConfig, BenchRunner};
use crate::util::cli::{CliError, Matches, Spec};

/// Entry point; returns the process exit code.
pub fn main(args: Vec<String>) -> i32 {
    match dispatch(args) {
        Ok(()) => 0,
        Err(e) => {
            // cooperative --help exits cleanly
            if let Some(CliError::Help(h)) = e.downcast_ref::<CliError>() {
                println!("{h}");
                return 0;
            }
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn dispatch(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        println!("{}", toplevel_help());
        return Ok(());
    };
    let rest = args[1..].to_vec();
    match cmd {
        "run" => cmd_run(&rest),
        "profile" => cmd_profile(&rest),
        "autotune" => cmd_autotune(&rest),
        "stats" => cmd_stats(&rest),
        "trace" => cmd_trace(&rest),
        "bench" => cmd_bench(&rest),
        "memplan" => cmd_memplan(&rest),
        "serve" => cmd_serve(&rest),
        "train" => cmd_train(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", toplevel_help());
            Ok(())
        }
        other => bail!("unknown command `{other}`\n\n{}", toplevel_help()),
    }
}

fn toplevel_help() -> String {
    "graphi — parallel execution engine for deep-learning computation graphs on manycore CPUs\n\
     (reproduction of Tang et al., 2018; see DESIGN.md)\n\n\
     COMMANDS:\n\
     \x20 run       run one experiment (config file or flags)\n\
     \x20 profile   §4.2 configuration search for a model\n\
     \x20 autotune  successive-halving parallel-setting search, persisted as a tuning artifact\n\
     \x20 stats     graph census + parallelism profile\n\
     \x20 trace     run once and export a Chrome trace + ASCII timeline\n\
     \x20 bench     regenerate a paper table/figure (fig2|fig3|fig5|fig6|table2|ablations|all)\n\
     \x20 serve     closed-loop multi-session serving on one persistent executor fleet\n\
     \x20 train     end-to-end LSTM-LM training through PJRT artifacts\n\n\
     Run `graphi <command> --help` for options."
        .to_string()
}

fn model_opts(spec: Spec) -> Spec {
    spec.opt("model", Some("lstm"), "model: lstm|phasedlstm|pathnet|googlenet|mlp")
        .opt("size", Some("medium"), "size: small|medium|large")
        .opt("seed", Some("42"), "rng seed")
}

fn parse_model(m: &Matches) -> Result<(ModelKind, ModelSize)> {
    let kind = ModelKind::parse(m.get("model").unwrap())
        .with_context(|| format!("bad --model {}", m.get("model").unwrap()))?;
    let size = ModelSize::parse(m.get("size").unwrap())
        .with_context(|| format!("bad --size {}", m.get("size").unwrap()))?;
    Ok((kind, size))
}

fn cmd_run(args: &[String]) -> Result<()> {
    let spec = model_opts(Spec::new("run", "run one experiment"))
        .opt("config", None, "TOML config file (flags override)")
        .opt("engine", Some("graphi"), "engine: graphi|sequential|naive|tensorflow")
        .opt("executors", None, "executor count (omit to auto-profile)")
        .opt("threads", None, "threads per executor")
        .opt("policy", Some("cp-first"), "cp-first|fifo|lifo|random|anti-critical")
        .opt(
            "dispatch",
            None,
            "centralized|decentralized (default: tuning artifact or config, else centralized)",
        )
        .opt("iters", Some("5"), "iterations to average")
        .opt("tuning", None, "artifact dir with a persisted autotune result to reuse")
        .flag("widths", "adopt the artifact's gang-width plan (moldable ops; needs --tuning)")
        .opt("trace", None, "write Chrome trace JSON here")
        .opt("trace-chrome", None, "alias for --trace (session-aware Chrome/Perfetto trace)")
        .opt("json", None, "write result JSON here");
    let m = spec.parse(args).map_err(Error::new)?;
    let has_config = m.get("config").is_some();
    let mut cfg = match m.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    // config-file values survive unless the flag was given explicitly
    // ("flags override" — *defaulted* flags must not clobber the file)
    let flag_wins = |name: &str| !has_config || m.is_explicit(name);
    let (kind, size) = parse_model(&m)?;
    if flag_wins("model") {
        cfg.model = kind;
    }
    if flag_wins("size") {
        cfg.size = size;
    }
    if flag_wins("engine") {
        cfg.engine = EngineChoice::parse(m.get("engine").unwrap())
            .with_context(|| format!("bad --engine {}", m.get("engine").unwrap()))?;
    }
    if let Some(e) = m.get_usize("executors").map_err(Error::new)? {
        cfg.executors = Some(e);
    }
    if let Some(t) = m.get_usize("threads").map_err(Error::new)? {
        cfg.threads_per = Some(t);
    }
    if flag_wins("policy") {
        cfg.policy = Policy::parse(m.get("policy").unwrap())
            .with_context(|| format!("bad --policy {}", m.get("policy").unwrap()))?;
    }
    // no default value: --dispatch participates in the pinned three-way
    // precedence (flag > tuning artifact > config file > engine default,
    // `DispatchMode::resolve`) instead of being applied here directly
    let dispatch_flag = match m.get("dispatch") {
        Some(d) => Some(DispatchMode::parse(d).with_context(|| format!("bad --dispatch {d}"))?),
        None => None,
    };
    if flag_wins("iters") {
        cfg.iterations = m.get_usize("iters").map_err(Error::new)?.unwrap_or(5);
    }
    if flag_wins("seed") {
        cfg.seed = m.get_u64("seed").map_err(Error::new)?.unwrap_or(42);
    }
    if let Some(trace) = m.get("trace").or_else(|| m.get("trace-chrome")) {
        cfg.trace_path = Some(trace.to_string());
    }
    // --tuning DIR: reuse a persisted autotune result; otherwise just
    // settle the flag-vs-config dispatch precedence
    match m.get("tuning") {
        Some(dir) => apply_tuning(&mut cfg, dir, dispatch_flag, m.flag("widths")),
        None => {
            if m.flag("widths") {
                crate::log_warn!("--widths does nothing without --tuning (no artifact to adopt a width plan from)");
            }
            cfg.dispatch = DispatchMode::resolve(dispatch_flag, None, cfg.dispatch);
        }
    }
    let result = Driver::run(&cfg);
    print!("{}", result.render());
    if let Some(path) = m.get("json") {
        std::fs::write(path, result.to_json().to_string_pretty())?;
        println!("json written to {path}");
    }
    Ok(())
}

/// Apply a tuning-artifact directory to a run configuration: the
/// artifact's profiled duration table always feeds the scheduler's levels;
/// its fleet shape applies only when no flag/config pinned one; its
/// dispatch mode enters the **pinned precedence** `--dispatch flag >
/// artifact winner > config-file value > engine default`
/// ([`DispatchMode::resolve`] — before PR 4 a config-file value silently
/// beat the artifact); its phase plan is adopted unless an explicit flag
/// pins a uniform mode; its gang-width plan (moldable ops) is adopted
/// only when `adopt_widths` (the `--widths` flag) asks for it **and**
/// the artifact's fleet shape was adopted — the widths were tuned
/// against that shape. Artifacts tuned on different hardware or graphs
/// are skipped with a warning — one tuning directory can serve a
/// heterogeneous fleet. Public so the precedence is integration-testable.
pub fn apply_tuning(
    cfg: &mut ExperimentConfig,
    dir: &str,
    dispatch_flag: Option<DispatchMode>,
    adopt_widths: bool,
) {
    let tag = format!("{}-{}", cfg.model.name(), cfg.size.name());
    let machine = crate::cost::machine::Machine::knl7250();
    let key = MachineKey::of(&machine);
    // machine-keyed filename first; fall back to the machine-agnostic
    // legacy location (its in-file key is still checked below)
    let keyed = tuning_path_for(dir, &tag, &key);
    let path = if keyed.is_file() { keyed } else { tuning_path(dir, &tag) };
    let nodes = models::build(cfg.model, cfg.size).len();
    let config_dispatch = cfg.dispatch;
    let mut artifact_dispatch = None;
    match TuningArtifact::load(&path) {
        Ok(t) if t.matches_graph(nodes) && t.matches_machine(&machine) => {
            let fleet_adopted = cfg.executors.is_none() && cfg.threads_per.is_none();
            if fleet_adopted {
                println!(
                    "tuning artifact {}: fleet {}x{} ({} dispatch) + profiled levels ({} profiling iterations, reused)",
                    path.display(),
                    t.best.0,
                    t.best.1,
                    t.best_dispatch.name(),
                    t.total_profile_iterations
                );
                cfg.executors = Some(t.best.0);
                cfg.threads_per = Some(t.best.1);
            } else {
                println!(
                    "tuning artifact {}: fleet fixed by flags/config; using its profiled levels only",
                    path.display()
                );
            }
            artifact_dispatch = Some(t.best_dispatch);
            // the phase plan was searched at the artifact's fleet shape
            // (its width threshold is the winning executor count), so it
            // only applies when that fleet is actually adopted — and an
            // explicit --dispatch flag pins a uniform mode either way
            match (&t.phase_plan, dispatch_flag.is_none() && fleet_adopted) {
                (Some(plan), true) => {
                    println!("tuning artifact phase plan adopted: {}", plan.render());
                    cfg.phase_plan = Some(plan.clone());
                }
                (Some(_), false) => {
                    println!(
                        "ignoring the artifact's phase plan ({}): it was tuned for the \
                         artifact's fleet and an unpinned dispatch mode",
                        if dispatch_flag.is_some() {
                            "explicit --dispatch pins a uniform mode"
                        } else {
                            "fleet fixed by flags/config"
                        }
                    );
                }
                (None, _) => {}
            }
            match (&t.width_plan, adopt_widths) {
                (Some(plan), true) if fleet_adopted => {
                    println!("tuning artifact gang-width plan adopted: {}", plan.render());
                    cfg.width_plan = Some(plan.clone());
                }
                (Some(_), true) => {
                    println!(
                        "ignoring the artifact's gang-width plan: it was tuned against the \
                         artifact's fleet, which flags/config overrode"
                    );
                }
                (Some(plan), false) => {
                    println!(
                        "tuning artifact has a gang-width plan ({}); pass --widths to adopt it",
                        plan.render()
                    );
                }
                (None, true) => {
                    println!(
                        "--widths: the artifact has no gang-width plan (re-run \
                         `graphi autotune --widths --force` to search for one)"
                    );
                }
                (None, false) => {}
            }
            cfg.profiled_durations = Some(t.durations_us);
        }
        Ok(t) if !t.matches_machine(&machine) => {
            crate::log_warn!(
                "tuning artifact {} was tuned on {} but this machine is {}; profiling fresh",
                path.display(),
                t.machine,
                key
            );
        }
        Ok(t) => {
            crate::log_warn!(
                "tuning artifact {} covers {} ops but {}/{} has {}; profiling fresh",
                path.display(),
                t.graph_nodes,
                cfg.model.name(),
                cfg.size.name(),
                nodes
            );
        }
        Err(e) => {
            crate::log_warn!("no usable tuning artifact ({e}); profiling fresh");
        }
    }
    cfg.dispatch = DispatchMode::resolve(dispatch_flag, artifact_dispatch, config_dispatch);
}

fn cmd_profile(args: &[String]) -> Result<()> {
    let spec = model_opts(Spec::new("profile", "§4.2 configuration search"))
        .opt("iters", Some("3"), "iterations per candidate");
    let m = spec.parse(args).map_err(Error::new)?;
    let (kind, size) = parse_model(&m)?;
    let graph = models::build(kind, size);
    let stats = GraphStats::compute(&graph);
    let profiler = Profiler {
        iterations: m.get_usize("iters").map_err(Error::new)?.unwrap_or(3),
        worker_cores: 64,
        extra_configs: crate::sim::topology::model_extras(stats.max_width),
    };
    let env = SimEnv::knl(m.get_u64("seed").map_err(Error::new)?.unwrap_or(42));
    let report = profiler.profile(&graph, &env);
    println!("profiling {}/{} ({} nodes)", kind.name(), size.name(), graph.len());
    print!("{}", Profiler::render(&report));
    println!("best: {}x{}", report.best.0, report.best.1);
    println!("static suggestion (graph width): {} executors", stats.suggested_executors());
    Ok(())
}

fn cmd_autotune(args: &[String]) -> Result<()> {
    let spec = model_opts(Spec::new(
        "autotune",
        "successive-halving parallel-setting search, persisted as a tuning artifact",
    ))
    .opt("dir", None, "artifact directory (default: $GRAPHI_ARTIFACTS or ./artifacts)")
    .opt("max-iters", Some("8"), "per-candidate iteration cap for late rounds")
    .opt("dispatch", Some("both"), "dispatch axis to search: both|centralized|decentralized")
    .flag("widths", "also search per-op-class gang widths (moldable ops)")
    .flag("force", "re-run the search even if a tuning artifact exists")
    .flag("compare", "also run the exhaustive sweep and report the savings");
    let m = spec.parse(args).map_err(Error::new)?;
    let (kind, size) = parse_model(&m)?;
    let graph = models::build(kind, size);
    let stats = GraphStats::compute(&graph);
    let seed = m.get_u64("seed").map_err(Error::new)?.unwrap_or(42);
    let env = SimEnv::knl(seed);
    let dispatch_modes = match m.get("dispatch").unwrap() {
        "both" => DispatchMode::ALL.to_vec(),
        other => vec![DispatchMode::parse(other)
            .with_context(|| format!("bad --dispatch {other} (both|centralized|decentralized)"))?],
    };
    let tuner = Autotuner {
        worker_cores: 64,
        // same §7.3 model-specific extras as `profile` and the driver
        extra_configs: crate::sim::topology::model_extras(stats.max_width),
        dispatch_modes,
        max_iterations: m.get_usize("max-iters").map_err(Error::new)?.unwrap_or(8),
        width_search: m.flag("widths"),
        ..Default::default()
    };
    let dir = m
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(crate::runtime::artifacts::default_dir);
    let tag = format!("{}-{}", kind.name(), size.name());
    // machine-keyed filename: artifacts from differently-shaped machines
    // coexist in one tuning directory instead of clobbering each other
    let path = tuning_path_for(&dir, &tag, &MachineKey::of(&env.cost.machine));
    if !m.flag("force") {
        if let Ok(t) = TuningArtifact::load(&path) {
            if t.matches_graph(graph.len()) && t.matches_machine(&env.cost.machine) {
                println!("loaded tuning artifact {} — skipping search", path.display());
                println!(
                    "best parallel setting: {}x{} ({} dispatch)  (mean makespan {}, found in {} profiling iterations)",
                    t.best.0,
                    t.best.1,
                    t.best_dispatch.name(),
                    crate::util::fmt_us(t.best_makespan_us),
                    t.total_profile_iterations
                );
                if let Some(plan) = &t.phase_plan {
                    println!("per-phase plan: {}", plan.render());
                }
                if let Some(plan) = &t.width_plan {
                    println!("gang-width plan: {}", plan.render());
                } else if m.flag("widths") {
                    crate::log_warn!(
                        "artifact {} has no gang-width plan; pass --force to re-search with widths",
                        path.display()
                    );
                }
                return Ok(());
            }
            crate::log_warn!(
                "tuning artifact {} does not match this graph/machine; re-searching",
                path.display()
            );
        }
    }
    println!("autotuning {}/{} ({} nodes)", kind.name(), size.name(), graph.len());
    let report = tuner.search(&graph, &env);
    print!("{}", Autotuner::render(&report));
    let artifact = TuningArtifact::from_report(&tag, graph.len(), &env, &tuner, &report);
    artifact.save(&path)?;
    println!("tuning artifact written to {}", path.display());
    if m.flag("compare") {
        let profiler = Profiler {
            iterations: report.final_round_iterations,
            worker_cores: tuner.worker_cores,
            extra_configs: tuner.extra_configs.clone(),
        };
        let exhaustive = profiler.profile(&graph, &env);
        let exhaustive_iters = profiler.candidates().len() * profiler.iterations;
        let det = SimEnv::knl_deterministic();
        let found = GraphiEngine::new(report.best.0, report.best.1)
            .with_dispatch(report.best_dispatch)
            .run(&graph, &det)
            .makespan_us;
        let sweep = GraphiEngine::new(exhaustive.best.0, exhaustive.best.1)
            .run(&graph, &det)
            .makespan_us;
        println!(
            "exhaustive sweep: best {}x{} in {} iterations; search spent {} ({:.0}% fewer)",
            exhaustive.best.0,
            exhaustive.best.1,
            exhaustive_iters,
            report.total_profile_iterations,
            100.0 * (1.0 - report.total_profile_iterations as f64 / exhaustive_iters as f64),
        );
        println!("found-makespan ratio (search/exhaustive): {:.3}", found / sweep);
    }
    Ok(())
}

fn cmd_stats(args: &[String]) -> Result<()> {
    let spec = model_opts(Spec::new("stats", "graph census"))
        .opt("dot", None, "write DOT file here")
        .opt("tuning", None, "artifact dir: also print the gang-width histogram for this graph");
    let m = spec.parse(args).map_err(Error::new)?;
    let (kind, size) = parse_model(&m)?;
    let graph = models::build(kind, size);
    println!("{}/{}", kind.name(), size.name());
    print!("{}", GraphStats::compute(&graph).render());
    // §5.1 memory plan over the topological order: the peak footprint is
    // what serve-mode admission charges against the MCDRAM budget
    let plan = crate::graph::plan_memory(&graph, &graph.topo_order());
    println!("memory plan (§5.1): {}", plan.summary_line());
    // --tuning DIR: per-op-class width histogram — how many ops of each
    // class the artifact's gang-width plan molds, and to what width
    if let Some(dir) = m.get("tuning") {
        use crate::graph::op::OpClass;
        let tag = format!("{}-{}", kind.name(), size.name());
        let machine = crate::cost::machine::Machine::knl7250();
        let keyed = tuning_path_for(dir, &tag, &MachineKey::of(&machine));
        let path = if keyed.is_file() { keyed } else { tuning_path(dir, &tag) };
        match TuningArtifact::load(&path) {
            Ok(t) if !t.matches_graph(graph.len()) => crate::log_warn!(
                "tuning artifact {} covers {} ops but this graph has {}; skipping widths",
                path.display(),
                t.graph_nodes,
                graph.len()
            ),
            Ok(t) => match &t.width_plan {
                Some(wplan) => {
                    let mut counts = [0usize; OpClass::COUNT];
                    for n in graph.nodes() {
                        counts[n.kind.class().index()] += 1;
                    }
                    println!("gang widths ({}):", path.display());
                    for class in OpClass::ALL {
                        if counts[class.index()] == 0 {
                            continue;
                        }
                        println!(
                            "  {:12} {:5} ops  × width {}",
                            class.name(),
                            counts[class.index()],
                            wplan.width_for(class)
                        );
                    }
                }
                None => println!(
                    "tuning artifact {} has no gang-width plan (run `graphi autotune --widths`)",
                    path.display()
                ),
            },
            Err(e) => crate::log_warn!("no usable tuning artifact ({e}); skipping widths"),
        }
    }
    if let Some(path) = m.get("dot") {
        std::fs::write(path, crate::graph::dot::to_dot(&graph))?;
        println!("dot written to {path}");
    }
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let spec = model_opts(Spec::new("trace", "run once, export trace"))
        .opt("executors", Some("8"), "executor count")
        .opt("threads", Some("8"), "threads per executor")
        .opt("out", Some("reports/trace.json"), "Chrome trace path")
        .opt("width", Some("100"), "ASCII timeline width")
        .opt("check", None, "validate an existing Chrome trace file instead of running");
    let m = spec.parse(args).map_err(Error::new)?;
    // --check FILE: parse + well-formedness validation of any exported
    // trace (CI runs this against the serve exporter's output)
    if let Some(path) = m.get("check") {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
        let stats = match crate::engine::validate_chrome_trace(&text) {
            Ok(s) => s,
            Err(e) => bail!("invalid trace {path}: {e}"),
        };
        println!(
            "{path}: OK — {} processes, {} spans, {} instants [{}]",
            stats.processes,
            stats.spans,
            stats.instants,
            stats.instant_names.iter().cloned().collect::<Vec<_>>().join(", "),
        );
        return Ok(());
    }
    let (kind, size) = parse_model(&m)?;
    let graph = models::build(kind, size);
    let executors = m.get_usize("executors").map_err(Error::new)?.unwrap();
    let threads = m.get_usize("threads").map_err(Error::new)?.unwrap();
    let env = SimEnv::knl(m.get_u64("seed").map_err(Error::new)?.unwrap_or(42));
    let result = GraphiEngine::new(executors, threads).run(&graph, &env);
    let trace = Trace { records: result.records.clone() };
    let width = m.get_usize("width").map_err(Error::new)?.unwrap();
    print!("{}", trace.render_ascii(&graph, width));
    println!(
        "depth/start-time correlation: {:.3} (≈1 ⇒ §7.4's diagonal wavefront)",
        trace.depth_time_correlation(&graph)
    );
    let out = m.get("out").unwrap();
    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(out, trace.to_chrome_json(&graph))?;
    println!("chrome trace written to {out} (open in ui.perfetto.dev)");
    Ok(())
}

fn cmd_memplan(args: &[String]) -> Result<()> {
    let spec = model_opts(Spec::new("memplan", "memory plan (§5.1 buffer sharing)"))
        .flag("inference", "plan the forward-only graph");
    let m = spec.parse(args).map_err(Error::new)?;
    let (kind, size) = parse_model(&m)?;
    let graph = if m.flag("inference") {
        models::build_inference(kind, size)
    } else {
        models::build(kind, size)
    };
    let plan = crate::graph::plan_memory(&graph, &graph.topo_order());
    println!(
        "{}/{}{}: {} buffers",
        kind.name(),
        size.name(),
        if m.flag("inference") { " (inference)" } else { "" },
        plan.allocations.len()
    );
    println!("{}", plan.summary_line());
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let spec = Spec::new("bench", "regenerate a paper table/figure")
        .positional("figure", "fig2|fig3|fig5|fig6|table2|ablations|skylake|numa|all")
        .flag("fast", "small-size grid only (CI speed)")
        .opt("csv", None, "CSV output directory (default reports/)");
    let m = spec.parse(args).map_err(Error::new)?;
    let which = m.positional(0).unwrap().to_string();
    let fast = m.flag("fast");
    let csv_dir = m.get_or("csv", "reports");
    let sizes: Vec<ModelSize> = if fast {
        vec![ModelSize::Small]
    } else {
        vec![ModelSize::Small, ModelSize::Medium, ModelSize::Large]
    };
    let run_one = |name: &str| -> Result<()> {
        let mut runner = BenchRunner::with_config(
            name,
            BenchConfig { csv_path: Some(format!("{csv_dir}/{name}.csv")), ..BenchConfig::default() },
        );
        let text = match name {
            "fig2" => figures::fig2(&mut runner),
            "fig3" => figures::fig3(&mut runner),
            "fig5" => figures::fig5(&mut runner, &sizes),
            "fig6" => figures::fig6(&mut runner, &sizes),
            "table2" => figures::table2(&mut runner, if fast { ModelSize::Small } else { ModelSize::Medium }),
            "ablations" => figures::ablations(&mut runner),
            "skylake" => figures::skylake(&mut runner),
            "numa" => figures::numa(&mut runner),
            other => bail!("unknown figure `{other}`"),
        };
        println!("{text}");
        runner.finish();
        Ok(())
    };
    if which == "all" {
        for name in ["fig2", "fig3", "fig5", "fig6", "table2", "ablations", "skylake", "numa"] {
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(&which)
    }
}

/// Insert a tag before the file extension: `t.json` + `centralized` →
/// `t.centralized.json` (appended when there is no extension).
fn suffix_path(path: &str, tag: &str) -> String {
    match std::path::Path::new(path).extension().and_then(|e| e.to_str()) {
        Some(ext) => {
            let stem = &path[..path.len() - ext.len() - 1];
            format!("{stem}.{tag}.{ext}")
        }
        None => format!("{path}.{tag}"),
    }
}

/// Parse a `model=weight,model=weight` mix (weight defaults to 1).
fn parse_mix(text: &str) -> Result<Vec<(ModelKind, f64)>> {
    let mut mix = Vec::new();
    for part in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (name, weight) = match part.split_once('=') {
            Some((n, w)) => {
                let w: f64 = w
                    .parse()
                    .ok()
                    .filter(|w: &f64| *w > 0.0 && w.is_finite())
                    .with_context(|| format!("bad mix weight in `{part}`"))?;
                (n, w)
            }
            None => (part, 1.0),
        };
        let kind =
            ModelKind::parse(name).with_context(|| format!("bad mix model `{name}`"))?;
        mix.push((kind, weight));
    }
    if mix.is_empty() {
        bail!("empty --mix");
    }
    Ok(mix)
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let spec = Spec::new(
        "serve",
        "closed-loop multi-session serving on one persistent executor fleet",
    )
    .opt("executors", Some("4"), "executor threads in the shared fleet")
    .opt("dispatch", Some("both"), "both|centralized|decentralized")
    .opt("clients", Some("4"), "closed-loop client threads (concurrent sessions)")
    .opt("requests", Some("200"), "total sessions per dispatch mode")
    .opt("size", Some("small"), "model size: small|medium|large")
    .opt(
        "mix",
        Some("lstm=1,mlp=1,googlenet=1,pathnet=1"),
        "weighted model mix, e.g. lstm=2,mlp=1",
    )
    .opt("budget-mb", Some("16384"), "§5.1 admission budget (MB of planned peak footprint)")
    .opt("max-sessions", Some("32"), "fleet session-slot cap")
    .opt("op-us", Some("0"), "busy-spin per op in µs (0 = scheduling-only)")
    .opt(
        "fault-rate",
        Some("0"),
        "probability a request draws a seeded fault plan (op panic / op delay / client cancel)",
    )
    .opt(
        "deadline-us",
        None,
        "per-session deadline in µs; late sessions fail with DeadlineExceeded, admission timeouts are shed",
    )
    .opt("arrival", Some("closed"), "arrival process: closed|poisson|bursty (open loop needs --rps)")
    .opt(
        "rps",
        None,
        "offered load for open-loop arrivals; a comma list sweeps the points and reports the \
         latency-vs-throughput knee (put ≈2× capacity last for the shed headline)",
    )
    .opt("admission", Some("fifo"), "admission order: fifo|priority|edf")
    .opt(
        "max-batch",
        Some("1"),
        "merge up to N compatible waiting requests into one batched session (open loop only)",
    )
    .opt(
        "batch-window-us",
        Some("200"),
        "how long a batch leader waits for compatible requests before admitting",
    )
    .opt("queue-depth", None, "bounded admission queue depth; overflow is shed as queue_full")
    .opt(
        "trace-sample",
        Some("1"),
        "record op spans for 1-in-N sessions in the chrome trace (lifecycle always recorded)",
    )
    .opt(
        "trace-chrome",
        None,
        "write a per-session Chrome/Perfetto trace here (suffixed per mode when --dispatch both)",
    )
    .opt("telemetry-every-ms", None, "print an aggregate telemetry line every N ms while serving")
    .opt("telemetry-ring", Some("1024"), "capacity of the bounded ring of recent session samples")
    .opt(
        "widths",
        None,
        "per-op-class gang-width plan for moldable ops, e.g. gemm=4,conv=2 (unlisted classes run at width 1)",
    )
    .opt("seed", Some("42"), "request-mix seed")
    .flag("training", "serve training graphs instead of forward-only inference graphs")
    .flag("bench-json", "append serve_throughput_* headlines to BENCH_scheduler.json");
    let m = spec.parse(args).map_err(Error::new)?;
    let size = ModelSize::parse(m.get("size").unwrap())
        .with_context(|| format!("bad --size {}", m.get("size").unwrap()))?;
    let mix = parse_mix(m.get("mix").unwrap())?;
    let modes = match m.get("dispatch").unwrap() {
        "both" => DispatchMode::ALL.to_vec(),
        other => vec![DispatchMode::parse(other)
            .with_context(|| format!("bad --dispatch {other} (both|centralized|decentralized)"))?],
    };
    let budget_mb = m.get_u64("budget-mb").map_err(Error::new)?.unwrap();
    // validate counts up front so bad flags get the one-line CLI error
    // every other option produces, not a panic from serve()/Fleet::new
    let positive = |name: &str| -> Result<usize> {
        let v = m.get_usize(name).map_err(Error::new)?.unwrap();
        if v == 0 {
            bail!("--{name} must be at least 1");
        }
        Ok(v)
    };
    let max_sessions = positive("max-sessions")?;
    if max_sessions > crate::runtime::fleet::MAX_SESSIONS {
        bail!(
            "--max-sessions {} exceeds the fleet's slot field cap of {}",
            max_sessions,
            crate::runtime::fleet::MAX_SESSIONS
        );
    }
    let fault_rate = m.get_f64("fault-rate").map_err(Error::new)?.unwrap();
    if !(0.0..=1.0).contains(&fault_rate) {
        bail!("--fault-rate must be within [0, 1], got {fault_rate}");
    }
    let deadline_us = m.get_u64("deadline-us").map_err(Error::new)?;
    if deadline_us == Some(0) {
        bail!("--deadline-us must be at least 1");
    }
    let telemetry_every_ms = m.get_u64("telemetry-every-ms").map_err(Error::new)?;
    if telemetry_every_ms == Some(0) {
        bail!("--telemetry-every-ms must be at least 1");
    }
    let telemetry_ring = positive("telemetry-ring")?;
    let trace_chrome = m.get("trace-chrome").map(|s| s.to_string());
    let admission = {
        let s = m.get("admission").unwrap();
        crate::runtime::AdmissionPolicy::parse(s)
            .with_context(|| format!("bad --admission {s} (fifo|priority|edf)"))?
    };
    let rps_points: Option<Vec<f64>> = match m.get("rps") {
        None => None,
        Some(text) => {
            let mut pts = Vec::new();
            for part in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                let v: f64 = part
                    .parse()
                    .ok()
                    .filter(|v: &f64| v.is_finite() && *v > 0.0)
                    .with_context(|| format!("bad --rps point `{part}`"))?;
                pts.push(v);
            }
            if pts.is_empty() {
                bail!("empty --rps");
            }
            Some(pts)
        }
    };
    let arrival_name = m.get("arrival").unwrap();
    let arrival = match (arrival_name, &rps_points) {
        ("closed", None) => crate::runtime::Arrival::Closed,
        ("closed", Some(_)) => bail!("--rps needs an open-loop --arrival (poisson|bursty)"),
        ("poisson", Some(p)) => crate::runtime::Arrival::Poisson { rps: p[0] },
        ("bursty", Some(p)) => crate::runtime::Arrival::Bursty { rps: p[0] },
        ("poisson" | "bursty", None) => bail!("--arrival {arrival_name} needs --rps"),
        (other, _) => bail!("bad --arrival {other} (closed|poisson|bursty)"),
    };
    let max_batch = positive("max-batch")?;
    if max_batch > 256 {
        bail!("--max-batch {max_batch} exceeds the 256-way batching cap");
    }
    if max_batch > 1 && matches!(arrival, crate::runtime::Arrival::Closed) {
        bail!(
            "--max-batch > 1 needs an open-loop --arrival (poisson|bursty): closed-loop \
             clients self-throttle, so there is nothing waiting to merge"
        );
    }
    let batch_window_us = m.get_u64("batch-window-us").map_err(Error::new)?.unwrap();
    let sweep_points = rps_points.as_ref().filter(|p| p.len() > 1);
    if sweep_points.is_some() && trace_chrome.is_some() {
        bail!("--trace-chrome with a multi-point --rps sweep would overwrite itself per point");
    }
    let queue_depth = m.get_u64("queue-depth").map_err(Error::new)?;
    if queue_depth == Some(0) {
        bail!("--queue-depth must be at least 1");
    }
    let trace_sample = m.get_u64("trace-sample").map_err(Error::new)?.unwrap();
    if trace_sample == 0 {
        bail!("--trace-sample must be at least 1");
    }
    let width_plan = match m.get("widths") {
        None => None,
        Some(text) => match WidthPlan::parse(text) {
            Ok(plan) => Some(plan),
            Err(e) => bail!("bad --widths: {e}"),
        },
    };
    let base = crate::runtime::ServeConfig {
        executors: positive("executors")?,
        clients: positive("clients")?,
        requests: positive("requests")?,
        size,
        mix,
        training: m.flag("training"),
        budget_bytes: budget_mb.saturating_mul(1 << 20),
        max_sessions,
        op_spin_us: m.get_f64("op-us").map_err(Error::new)?.unwrap(),
        fault_rate,
        deadline_us,
        telemetry_every_ms,
        telemetry_ring,
        seed: m.get_u64("seed").map_err(Error::new)?.unwrap(),
        arrival,
        admission,
        queue_depth,
        trace_sample,
        batch_window_us,
        max_batch,
        width_plan,
        ..crate::runtime::ServeConfig::default()
    };
    let mut runner = m
        .flag("bench-json")
        .then(|| BenchRunner::with_config("serve_throughput", BenchConfig::default()));
    let mut headlines: Vec<(String, f64)> = Vec::new();
    let multi_mode = modes.len() > 1;
    for mode in modes {
        // one trace file per dispatch mode when --dispatch both runs two
        let trace_path = trace_chrome.as_ref().map(|p| {
            if multi_mode { suffix_path(p, mode.name()) } else { p.clone() }
        });
        let cfg =
            crate::runtime::ServeConfig { dispatch: mode, trace_path, ..base.clone() };
        if let Some(points) = sweep_points {
            // offered-load sweep: one fresh fleet per point, knee reported
            let sweep = crate::runtime::serve_sweep(&cfg, points);
            print!("{}", sweep.render());
            if let Some(runner) = runner.as_mut() {
                let labels = [
                    ("dispatch", mode.name().to_string()),
                    ("executors", cfg.executors.to_string()),
                    ("arrival", cfg.arrival.name().to_string()),
                    ("admission", cfg.admission.name().to_string()),
                    ("rps_points", points.len().to_string()),
                ];
                let wall_us: f64 =
                    sweep.points.iter().map(|p| p.report.wall_s * 1e6).sum();
                if let Some(knee) = sweep.knee_rps {
                    runner.record_with_metric(
                        &format!("serve_knee_rps_{}", mode.name()),
                        &labels,
                        wall_us,
                        Some((knee, "rps")),
                    );
                    headlines.push((format!("serve_knee_rps_{}", mode.name()), knee));
                }
                // by convention the sweep's last point sits at ≈2× the
                // analytic capacity, so its shed fraction is the overload
                // headline (see --rps help)
                if let Some(last) = sweep.points.last() {
                    let frac = last.report.shed_fraction();
                    runner.record_with_metric(
                        &format!("serve_shed_fraction_at_2x_{}", mode.name()),
                        &labels,
                        last.report.wall_s * 1e6,
                        Some((frac, "fraction")),
                    );
                    headlines
                        .push((format!("serve_shed_fraction_at_2x_{}", mode.name()), frac));
                }
            }
            continue;
        }
        let report = crate::runtime::serve(&cfg);
        print!("{}", report.render());
        if let Some(path) = &cfg.trace_path {
            println!("chrome trace written to {path} (open in ui.perfetto.dev)");
        }
        if let Some(runner) = runner.as_mut() {
            let labels = [
                ("dispatch", mode.name().to_string()),
                ("executors", cfg.executors.to_string()),
                ("clients", cfg.clients.to_string()),
                ("requests", cfg.requests.to_string()),
            ];
            runner.record(
                &format!("serve_session_p50_{}", mode.name()),
                &labels,
                report.latency_us.p50,
            );
            runner.record(
                &format!("serve_session_p99_{}", mode.name()),
                &labels,
                report.latency_us.p99,
            );
            // throughput gets its own record (value = run wall time) so
            // the sessions/s metric never rides on a latency row
            runner.record_with_metric(
                &format!("serve_throughput_{}", mode.name()),
                &labels,
                report.wall_s * 1e6,
                Some((report.throughput_rps, "sessions/s")),
            );
            headlines.push((
                format!("serve_throughput_rps_{}", mode.name()),
                report.throughput_rps,
            ));
            headlines.push((
                format!("serve_p99_latency_us_{}", mode.name()),
                report.latency_us.p99,
            ));
            if cfg.max_batch > 1 {
                runner.record_with_metric(
                    &format!("serve_batched_fraction_{}", mode.name()),
                    &labels,
                    report.wall_s * 1e6,
                    Some((report.batched_fraction, "fraction")),
                );
                headlines.push((
                    format!("serve_batched_fraction_{}", mode.name()),
                    report.batched_fraction,
                ));
            }
        }
    }
    if let Some(runner) = &runner {
        let refs: Vec<(&str, f64)> = headlines.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        crate::util::bench::merge_into_bench_json(runner, &refs);
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let spec = Spec::new("train", "end-to-end LSTM-LM training via PJRT artifacts")
        .opt("steps", Some("200"), "training steps")
        .opt("artifacts", None, "artifact directory (default: $GRAPHI_ARTIFACTS or ./artifacts)")
        .opt("seed", Some("42"), "init + corpus seed")
        .opt("log-every", Some("20"), "steps between loss logs")
        .opt("curve", None, "write the loss curve to this file");
    let m = spec.parse(args).map_err(Error::new)?;
    let dir = m
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(crate::runtime::artifacts::default_dir);
    let set = crate::runtime::ArtifactSet::load(&dir)?;
    let runtime = crate::runtime::PjrtRuntime::cpu()?;
    println!("platform: {}", runtime.platform());
    let seed = m.get_u64("seed").map_err(Error::new)?.unwrap();
    let mut trainer = crate::runtime::LstmTrainer::new(&runtime, &set, seed)?;
    println!("params: {}", trainer.param_count());
    let (pe, pt) = trainer.parallelism();
    println!(
        "parallel setting: {pe}x{pt}{}",
        if trainer.parallelism_from_tuning() {
            " (from tuning artifact)"
        } else {
            " (default — run `graphi autotune` to tune)"
        }
    );
    let steps = m.get_usize("steps").map_err(Error::new)?.unwrap();
    let log_every = m.get_usize("log-every").map_err(Error::new)?.unwrap();
    let report = trainer.train(steps, seed ^ 0xC0DE, log_every)?;
    println!(
        "\ntrained {} steps in {:.1}s ({:.2} steps/s)\ninitial loss {:.4} → final loss {:.4}",
        report.steps,
        report.wall_s,
        report.steps_per_s,
        report.initial_loss(),
        report.final_loss()
    );
    print!("{}", report.render_curve(20));
    if let Some(path) = m.get("curve") {
        let mut text = String::from("step,loss\n");
        for (i, l) in report.losses.iter().enumerate() {
            text.push_str(&format!("{i},{l}\n"));
        }
        std::fs::write(path, text)?;
        println!("curve written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn no_args_prints_help() {
        assert_eq!(main(vec![]), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(main(args(&["frobnicate"])), 1);
    }

    #[test]
    fn run_mlp_quick() {
        assert_eq!(
            main(args(&[
                "run", "--model", "mlp", "--size", "small", "--executors", "4", "--threads", "8",
                "--iters", "1"
            ])),
            0
        );
    }

    #[test]
    fn stats_command() {
        assert_eq!(main(args(&["stats", "--model", "pathnet", "--size", "small"])), 0);
    }

    #[test]
    fn help_for_subcommand() {
        assert_eq!(main(args(&["run", "--help"])), 0);
    }

    #[test]
    fn autotune_writes_then_reuses_artifact() {
        let dir = std::env::temp_dir().join(format!("graphi-cli-autotune-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.display().to_string();
        let base = ["autotune", "--model", "mlp", "--size", "small", "--dir", &dir_s];
        assert_eq!(main(args(&base)), 0);
        // written under the machine-keyed filename (KNL quadrant = 68c1d)
        let key = crate::runtime::artifacts::MachineKey { cores: 68, numa_domains: 1 };
        let path = crate::runtime::artifacts::tuning_path_for(&dir, "mlp-small", &key);
        assert!(path.is_file(), "artifact not written to {}", path.display());
        // second invocation loads the artifact (and must not fail)
        assert_eq!(main(args(&base)), 0);
        // run can consume it
        assert_eq!(
            main(args(&[
                "run", "--model", "mlp", "--size", "small", "--iters", "1", "--tuning", &dir_s,
            ])),
            0
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_model_rejected() {
        assert_eq!(main(args(&["stats", "--model", "resnet"])), 1);
    }

    #[test]
    fn run_accepts_dispatch_flag() {
        assert_eq!(
            main(args(&[
                "run", "--model", "mlp", "--size", "small", "--executors", "4", "--threads", "8",
                "--iters", "1", "--dispatch", "decentralized"
            ])),
            0
        );
        assert_eq!(
            main(args(&["run", "--model", "mlp", "--size", "small", "--dispatch", "sideways"])),
            1
        );
    }

    #[test]
    fn tuning_dispatch_precedence_flag_beats_artifact_beats_config() {
        use crate::engine::PhasePlan;
        use crate::runtime::artifacts::{tuning_path_for, MachineKey, TuningArtifact, TUNING_FORMAT_VERSION};
        let dir = std::env::temp_dir()
            .join(format!("graphi-cli-precedence-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.display().to_string();
        // forge a valid mlp-small artifact whose winner is decentralized
        // and which carries a (single-phase) plan
        let nodes = models::build(ModelKind::Mlp, ModelSize::Small).len();
        let machine = crate::cost::machine::Machine::knl7250();
        let plan = PhasePlan::uniform(1, DispatchMode::Decentralized, 1);
        let wplan = {
            let mut p = WidthPlan::uniform(1);
            p.set(crate::graph::op::OpClass::Gemm, 4);
            p
        };
        let artifact = TuningArtifact {
            version: TUNING_FORMAT_VERSION,
            tag: "mlp-small".to_string(),
            worker_cores: 64,
            seed: 0,
            machine: MachineKey::of(&machine),
            graph_nodes: nodes,
            best: (4, 8),
            best_dispatch: DispatchMode::Decentralized,
            phase_plan: Some(plan.clone()),
            width_plan: Some(wplan.clone()),
            best_makespan_us: 1.0,
            total_profile_iterations: 1,
            durations_us: vec![1.0; nodes],
            search_trace: Vec::new(),
        };
        artifact
            .save(tuning_path_for(&dir, "mlp-small", &MachineKey::of(&machine)))
            .unwrap();
        let base = || ExperimentConfig {
            model: ModelKind::Mlp,
            size: ModelSize::Small,
            ..ExperimentConfig::default()
        };

        // artifact beats a config-file value (the PR-4 precedence fix:
        // previously `engine.dispatch` in the TOML silently won)
        let mut cfg = base();
        cfg.dispatch = Some(DispatchMode::Centralized); // "from the config file"
        apply_tuning(&mut cfg, &dir_s, None, false);
        assert_eq!(cfg.dispatch, Some(DispatchMode::Decentralized));
        assert_eq!(cfg.phase_plan, Some(plan.clone()));
        assert_eq!(cfg.executors, Some(4));
        // widths are opt-in: without the flag the artifact's plan stays put
        assert_eq!(cfg.width_plan, None, "widths need --widths");

        // --widths + adopted fleet: the gang-width plan comes along
        let mut cfg = base();
        apply_tuning(&mut cfg, &dir_s, None, true);
        assert_eq!(cfg.width_plan, Some(wplan.clone()));

        // an explicit flag beats the artifact and pins a uniform mode
        // (phase plan dropped)
        let mut cfg = base();
        cfg.dispatch = Some(DispatchMode::Decentralized);
        apply_tuning(&mut cfg, &dir_s, Some(DispatchMode::Centralized), false);
        assert_eq!(cfg.dispatch, Some(DispatchMode::Centralized));
        assert_eq!(cfg.phase_plan, None);

        // a pinned fleet keeps the artifact's levels and dispatch winner,
        // but NOT its phase plan or width plan (both were searched at the
        // artifact's own fleet shape)
        let mut cfg = base();
        cfg.executors = Some(2);
        cfg.threads_per = Some(4);
        apply_tuning(&mut cfg, &dir_s, None, true);
        assert_eq!(cfg.dispatch, Some(DispatchMode::Decentralized));
        assert_eq!(cfg.phase_plan, None, "plan tuned for another fleet must not apply");
        assert_eq!(cfg.width_plan, None, "widths tuned for another fleet must not apply");
        assert_eq!(cfg.executors, Some(2));
        assert!(cfg.profiled_durations.is_some());

        // no usable artifact: flag > config, config survives an absent flag
        let empty = std::env::temp_dir()
            .join(format!("graphi-cli-precedence-empty-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&empty);
        std::fs::create_dir_all(&empty).unwrap();
        let empty_s = empty.display().to_string();
        let mut cfg = base();
        cfg.dispatch = Some(DispatchMode::Decentralized);
        apply_tuning(&mut cfg, &empty_s, None, false);
        assert_eq!(cfg.dispatch, Some(DispatchMode::Decentralized), "config survives");
        let mut cfg = base();
        cfg.dispatch = Some(DispatchMode::Decentralized);
        apply_tuning(&mut cfg, &empty_s, Some(DispatchMode::Centralized), false);
        assert_eq!(cfg.dispatch, Some(DispatchMode::Centralized), "flag wins");
        // nothing anywhere ⇒ stays unpinned (engine default later)
        let mut cfg = base();
        apply_tuning(&mut cfg, &empty_s, None, true);
        assert_eq!(cfg.dispatch, None);
        assert_eq!(cfg.width_plan, None, "no artifact, no widths");

        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&empty).unwrap();
    }

    #[test]
    fn serve_smoke_runs_both_modes() {
        assert_eq!(
            main(args(&[
                "serve", "--requests", "6", "--clients", "2", "--executors", "2", "--mix",
                "mlp=1", "--size", "small",
            ])),
            0
        );
    }

    #[test]
    fn serve_rejects_bad_mix_and_dispatch() {
        assert_eq!(
            main(args(&["serve", "--requests", "2", "--mix", "resnet=1"])),
            1
        );
        assert_eq!(
            main(args(&["serve", "--requests", "2", "--mix", "mlp=-1"])),
            1
        );
        assert_eq!(
            main(args(&["serve", "--requests", "2", "--dispatch", "sideways"])),
            1
        );
        assert_eq!(main(args(&["serve", "--mix", ","])), 1);
        // zero / out-of-range counts get the friendly CLI error, not a panic
        assert_eq!(main(args(&["serve", "--requests", "0"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--executors", "0"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--clients", "0"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--max-sessions", "300"])), 1);
        // fault-injection flags are validated up front too
        assert_eq!(main(args(&["serve", "--requests", "2", "--fault-rate", "1.5"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--fault-rate", "-0.1"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--deadline-us", "0"])), 1);
    }

    #[test]
    fn serve_open_loop_smoke_and_sweep() {
        // single-point open loop with a deadline, admission policy and a
        // bounded queue: must exit 0 in one mode
        assert_eq!(
            main(args(&[
                "serve", "--requests", "8", "--executors", "2", "--mix", "mlp=1", "--size",
                "small", "--dispatch", "decentralized", "--arrival", "poisson", "--rps",
                "500", "--admission", "edf", "--queue-depth", "4", "--deadline-us",
                "5000000", "--max-batch", "3", "--batch-window-us", "2000",
            ])),
            0
        );
        // a comma list sweeps: two points, bursty shape, priority order
        assert_eq!(
            main(args(&[
                "serve", "--requests", "6", "--executors", "2", "--mix", "mlp=1", "--size",
                "small", "--dispatch", "decentralized", "--arrival", "bursty", "--rps",
                "400,800", "--admission", "priority",
            ])),
            0
        );
    }

    #[test]
    fn serve_rejects_bad_open_loop_flags() {
        // open-loop shapes need a load; closed must not get one
        assert_eq!(main(args(&["serve", "--requests", "2", "--arrival", "poisson"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--rps", "100"])), 1);
        assert_eq!(
            main(args(&["serve", "--requests", "2", "--arrival", "sideways", "--rps", "10"])),
            1
        );
        assert_eq!(
            main(args(&["serve", "--requests", "2", "--arrival", "poisson", "--rps", "-5"])),
            1
        );
        assert_eq!(
            main(args(&["serve", "--requests", "2", "--arrival", "poisson", "--rps", ","])),
            1
        );
        assert_eq!(main(args(&["serve", "--requests", "2", "--admission", "lifo"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--queue-depth", "0"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--trace-sample", "0"])), 1);
        // batching needs an open-loop arrival process and a sane cap
        assert_eq!(main(args(&["serve", "--requests", "2", "--max-batch", "4"])), 1);
        assert_eq!(main(args(&["serve", "--requests", "2", "--max-batch", "0"])), 1);
        assert_eq!(
            main(args(&[
                "serve", "--requests", "2", "--arrival", "poisson", "--rps", "100",
                "--max-batch", "300",
            ])),
            1
        );
        // a multi-point sweep would overwrite a single trace file
        assert_eq!(
            main(args(&[
                "serve", "--requests", "2", "--arrival", "poisson", "--rps", "10,20",
                "--trace-chrome", "/tmp/never-written.json",
            ])),
            1
        );
    }

    #[test]
    fn serve_trace_sampling_smoke_keeps_the_trace_valid() {
        let path = std::env::temp_dir()
            .join(format!("graphi-cli-serve-sampled-{}.json", std::process::id()));
        let path_s = path.display().to_string();
        assert_eq!(
            main(args(&[
                "serve", "--requests", "6", "--clients", "2", "--executors", "2", "--mix",
                "mlp=1", "--size", "small", "--dispatch", "decentralized", "--trace-chrome",
                &path_s, "--trace-sample", "3",
            ])),
            0
        );
        assert_eq!(main(args(&["trace", "--check", &path_s])), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn serve_fault_smoke_survives_injected_faults() {
        // seeded faults + a generous deadline: the run must exit 0 (faults
        // are reported, not fatal) in both dispatch modes
        assert_eq!(
            main(args(&[
                "serve", "--requests", "8", "--clients", "2", "--executors", "2", "--mix",
                "mlp=1", "--size", "small", "--fault-rate", "0.5", "--deadline-us", "5000000",
            ])),
            0
        );
    }

    #[test]
    fn run_trace_chrome_then_check_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("graphi-cli-run-trace-{}.json", std::process::id()));
        let path_s = path.display().to_string();
        assert_eq!(
            main(args(&[
                "run", "--model", "mlp", "--size", "small", "--executors", "4", "--threads",
                "8", "--iters", "1", "--trace-chrome", &path_s,
            ])),
            0
        );
        assert_eq!(main(args(&["trace", "--check", &path_s])), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn serve_trace_chrome_then_check_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("graphi-cli-serve-trace-{}.json", std::process::id()));
        let path_s = path.display().to_string();
        assert_eq!(
            main(args(&[
                "serve", "--requests", "6", "--clients", "2", "--executors", "2", "--mix",
                "mlp=1", "--size", "small", "--dispatch", "decentralized", "--trace-chrome",
                &path_s, "--telemetry-every-ms", "50",
            ])),
            0
        );
        assert_eq!(main(args(&["trace", "--check", &path_s])), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn trace_check_rejects_missing_and_garbage_files() {
        assert_eq!(main(args(&["trace", "--check", "/nonexistent/trace.json"])), 1);
        let path = std::env::temp_dir()
            .join(format!("graphi-cli-garbage-{}.json", std::process::id()));
        std::fs::write(&path, "{\"traceEvents\": \"nope\"}").unwrap();
        let path_s = path.display().to_string();
        assert_eq!(main(args(&["trace", "--check", &path_s])), 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn suffix_path_inserts_before_the_extension() {
        assert_eq!(suffix_path("t.json", "centralized"), "t.centralized.json");
        assert_eq!(suffix_path("reports/trace", "decentralized"), "reports/trace.decentralized");
        assert_eq!(suffix_path("a.b/t.json", "x"), "a.b/t.x.json");
    }

    #[test]
    fn parse_mix_defaults_weights_and_trims() {
        let mix = parse_mix("lstm=2, mlp ,pathnet=0.5").unwrap();
        assert_eq!(mix.len(), 3);
        assert_eq!(mix[0], (ModelKind::Lstm, 2.0));
        assert_eq!(mix[1], (ModelKind::Mlp, 1.0));
        assert_eq!(mix[2], (ModelKind::PathNet, 0.5));
        assert!(parse_mix("").is_err());
    }

    #[test]
    fn stats_reports_the_memory_plan() {
        // the §5.1 satellite: `graphi stats` must include the planner's
        // peak footprint (visually checked via exit code here; the plan
        // fields themselves are unit-tested in graph::memory)
        assert_eq!(main(args(&["stats", "--model", "mlp", "--size", "small"])), 0);
    }

    #[test]
    fn autotune_accepts_dispatch_axis_restriction() {
        let dir = std::env::temp_dir()
            .join(format!("graphi-cli-autotune-axis-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.display().to_string();
        assert_eq!(
            main(args(&[
                "autotune", "--model", "mlp", "--size", "small", "--dir", &dir_s, "--dispatch",
                "centralized"
            ])),
            0
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
