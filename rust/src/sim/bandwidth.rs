//! Shared-MCDRAM bandwidth arbitration.
//!
//! Concurrently running operations share the chip's memory bandwidth.
//! When the sum of their demands exceeds the MCDRAM limit, memory-bound
//! ops stretch proportionally. The arbiter uses a simple open-loop
//! approximation that keeps the simulation single-pass: an op's stretch
//! factor is fixed at dispatch time from the demand of the ops running at
//! that moment. This slightly underestimates contention for ops dispatched
//! early into a burst, which is acceptable for the paper's workloads (the
//! element-wise fraction of total time is modest).

/// Tracks aggregate bandwidth demand of in-flight operations.
#[derive(Debug)]
pub struct BandwidthArbiter {
    /// MCDRAM bandwidth budget, bytes/s.
    budget: f64,
    /// Demands of currently running ops, bytes/s, keyed by token.
    running: Vec<(u64, f64)>,
    next_token: u64,
}

impl BandwidthArbiter {
    pub fn new(budget_bytes_per_s: f64) -> BandwidthArbiter {
        BandwidthArbiter { budget: budget_bytes_per_s, running: Vec::new(), next_token: 0 }
    }

    /// Aggregate demand of in-flight ops, bytes/s.
    pub fn current_demand(&self) -> f64 {
        self.running.iter().map(|(_, d)| d).sum()
    }

    /// Register an op that will demand `demand` bytes/s; returns the
    /// stretch factor to apply to its duration and a token to release on
    /// completion.
    pub fn admit(&mut self, demand: f64) -> (f64, u64) {
        let token = self.next_token;
        self.next_token += 1;
        let total = self.current_demand() + demand;
        self.running.push((token, demand));
        let stretch = if total > self.budget { total / self.budget } else { 1.0 };
        (stretch, token)
    }

    /// Release a completed op's demand.
    pub fn release(&mut self, token: u64) {
        if let Some(pos) = self.running.iter().position(|(t, _)| *t == token) {
            self.running.swap_remove(pos);
        } else {
            debug_assert!(false, "double release of bandwidth token {token}");
        }
    }

    pub fn in_flight(&self) -> usize {
        self.running.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn under_budget_no_stretch() {
        let mut a = BandwidthArbiter::new(400e9);
        let (s1, t1) = a.admit(100e9);
        let (s2, _t2) = a.admit(200e9);
        assert_eq!(s1, 1.0);
        assert_eq!(s2, 1.0);
        a.release(t1);
        assert_eq!(a.current_demand(), 200e9);
    }

    #[test]
    fn over_budget_stretches_proportionally() {
        let mut a = BandwidthArbiter::new(400e9);
        let (_, _) = a.admit(300e9);
        let (s, _) = a.admit(300e9);
        assert!((s - 1.5).abs() < 1e-12, "600/400 = 1.5, got {s}");
    }

    #[test]
    fn release_restores_headroom() {
        let mut a = BandwidthArbiter::new(100e9);
        let (_, t) = a.admit(100e9);
        a.release(t);
        let (s, _) = a.admit(50e9);
        assert_eq!(s, 1.0);
        assert_eq!(a.in_flight(), 1);
    }
}
