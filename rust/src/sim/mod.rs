//! Discrete-event simulation substrate for the KNL manycore CPU.
//!
//! The paper's testbed (68-core Xeon Phi 7250) is unavailable, so the
//! engines in [`crate::engine`] execute against virtual time provided by
//! this module (DESIGN.md §2 and §5 explain the substitution and fidelity
//! model):
//!
//! * [`event`]     — the event queue (virtual clock, stable ordering)
//! * [`topology`]  — cores, tiles, and executor→core placement
//! * [`bandwidth`] — shared-MCDRAM bandwidth arbitration
//!
//! The *algorithms* under study (critical-path scheduling, ring buffers,
//! bitmap scans) are real Rust code; only durations are simulated.

pub mod bandwidth;
pub mod event;
pub mod topology;

pub use bandwidth::BandwidthArbiter;
pub use event::{EventQueue, SimTime};
pub use topology::{Placement, PlacementKind};
