//! Executor→core placement on the tiled manycore topology.
//!
//! KNL organizes cores in pairs ("tiles") sharing 1 MB of L2 (§2, Fig 1).
//! Graphi pins each executor's thread team to exclusive tiles so that
//! executors share neither cores nor L2 (§4.4). The OS-managed baseline
//! scatters threads, producing the collisions priced by
//! [`crate::cost::Interference`].

use crate::cost::machine::Machine;

/// How threads are bound to cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Graphi: pinned, executor-disjoint, tile-aligned.
    PinnedDisjoint,
    /// Pinned but deliberately overlapping tiles (ablation of §4.4).
    PinnedSharedTiles,
    /// OS-managed: no binding; collisions priced stochastically.
    OsManaged,
}

/// The concrete placement of a fleet of symmetric executors.
#[derive(Debug, Clone)]
pub struct Placement {
    pub kind: PlacementKind,
    /// `cores[e]` = physical core ids owned by executor `e` (empty for
    /// OS-managed placement).
    pub cores: Vec<Vec<usize>>,
    /// Core reserved for the centralized scheduler thread (§5.2).
    pub scheduler_core: Option<usize>,
    /// Core reserved for the light-weight executor (§5.2).
    pub lightweight_core: Option<usize>,
    /// Cores per tile of the machine this was computed for.
    cores_per_tile: usize,
}

/// Placement construction errors.
#[derive(Debug, PartialEq)]
pub enum PlacementError {
    NotEnoughCores {
        requested: usize,
        available: usize,
        total: usize,
        reserved: usize,
    },
    ZeroTeam,
    ZeroExecutors,
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementError::NotEnoughCores { requested, available, total, reserved } => write!(
                f,
                "{requested} worker cores requested but only {available} available \
                 (machine has {total}, {reserved} reserved for scheduler + light-weight executor)"
            ),
            PlacementError::ZeroTeam => write!(f, "executor team size must be > 0"),
            PlacementError::ZeroExecutors => write!(f, "executor count must be > 0"),
        }
    }
}

impl std::error::Error for PlacementError {}

impl Placement {
    /// Graphi's placement (§4.4 + §5.2): reserve one core for the
    /// scheduler and one for the light-weight executor, then hand each of
    /// the `executors` teams `threads_per` exclusive cores, tile-aligned
    /// (even team sizes never split a tile between executors).
    pub fn pinned_disjoint(
        machine: &Machine,
        executors: usize,
        threads_per: usize,
    ) -> Result<Placement, PlacementError> {
        Self::pinned(machine, executors, threads_per, true)
    }

    /// Ablation placement: pinned but packed without tile alignment, so
    /// adjacent executors share L2 tiles.
    pub fn pinned_shared_tiles(
        machine: &Machine,
        executors: usize,
        threads_per: usize,
    ) -> Result<Placement, PlacementError> {
        Self::pinned(machine, executors, threads_per, false)
    }

    fn pinned(
        machine: &Machine,
        executors: usize,
        threads_per: usize,
        tile_aligned: bool,
    ) -> Result<Placement, PlacementError> {
        if executors == 0 {
            return Err(PlacementError::ZeroExecutors);
        }
        if threads_per == 0 {
            return Err(PlacementError::ZeroTeam);
        }
        let reserved = 2; // scheduler + light-weight executor (§5.2, §7.3)
        let available = machine.cores.saturating_sub(reserved);
        let requested = executors * threads_per;
        if requested > available {
            return Err(PlacementError::NotEnoughCores {
                requested,
                available,
                total: machine.cores,
                reserved,
            });
        }
        let cpt = machine.cores_per_tile;
        // Reserve the two highest cores (the last tile) for scheduler + LW.
        let scheduler_core = machine.cores - 1;
        let lightweight_core = machine.cores - 2;
        let mut next_core = 0usize;
        let mut cores = Vec::with_capacity(executors);
        for _ in 0..executors {
            if tile_aligned {
                // round the executor's start up to a tile boundary so teams
                // of even size never straddle another executor's tile
                if threads_per >= cpt && next_core % cpt != 0 {
                    next_core += cpt - (next_core % cpt);
                }
            }
            let team: Vec<usize> = (next_core..next_core + threads_per).collect();
            next_core += threads_per;
            cores.push(team);
        }
        let kind = if tile_aligned {
            PlacementKind::PinnedDisjoint
        } else {
            PlacementKind::PinnedSharedTiles
        };
        Ok(Placement {
            kind,
            cores,
            scheduler_core: Some(scheduler_core),
            lightweight_core: Some(lightweight_core),
            cores_per_tile: cpt,
        })
    }

    /// OS-managed placement: `executors` logical executors, no binding.
    pub fn os_managed(executors: usize) -> Placement {
        Placement {
            kind: PlacementKind::OsManaged,
            cores: vec![Vec::new(); executors],
            scheduler_core: None,
            lightweight_core: None,
            cores_per_tile: 2,
        }
    }

    pub fn executors(&self) -> usize {
        self.cores.len()
    }

    /// Tile ids used by executor `e`.
    pub fn tiles_of(&self, e: usize) -> Vec<usize> {
        let mut tiles: Vec<usize> = self.cores[e].iter().map(|c| c / self.cores_per_tile).collect();
        tiles.sort_unstable();
        tiles.dedup();
        tiles
    }

    /// Do executors `a` and `b` share an L2 tile?
    pub fn executors_share_tile(&self, a: usize, b: usize) -> bool {
        if self.kind == PlacementKind::OsManaged {
            return true; // unknown placement — assume the worst
        }
        let ta = self.tiles_of(a);
        let tb = self.tiles_of(b);
        ta.iter().any(|t| tb.contains(t))
    }

    /// Does *any* executor pair share a tile? Graphi's §4.4 invariant is
    /// that this is false.
    pub fn any_tile_sharing(&self) -> bool {
        for a in 0..self.executors() {
            for b in (a + 1)..self.executors() {
                if self.executors_share_tile(a, b) {
                    return true;
                }
            }
        }
        false
    }

    /// Total worker threads across executors.
    pub fn total_threads(&self, threads_per: usize) -> usize {
        self.executors() * threads_per
    }

    /// Does executor `e`'s team span more than one NUMA domain of
    /// `machine`? (SNC modes only; quadrant is one domain.)
    pub fn executor_spans_domains(&self, machine: &Machine, e: usize) -> bool {
        if machine.numa_domains <= 1 || self.cores[e].is_empty() {
            return false;
        }
        let first = machine.domain_of_core(self.cores[e][0]);
        self.cores[e].iter().any(|&c| machine.domain_of_core(c) != first)
    }
}

/// The symmetric configurations the profiler enumerates (§4.2): for a
/// 64-core worker pool, `1×64, 2×32, …, 64×1`, plus any model-specific
/// extras the caller appends (6×10 for PathNet, 3×21 for GoogleNet).
pub fn symmetric_configs(worker_cores: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut k = 1usize;
    while k <= worker_cores {
        out.push((k, worker_cores / k));
        k *= 2;
    }
    out.retain(|&(_, t)| t > 0);
    out
}

/// The full candidate space the profiler/autotuner searches: the symmetric
/// power-of-two splits plus caller-supplied model-specific extras (§7.3's
/// 6×10 for PathNet, 3×21 for GoogleNet), deduplicated, with degenerate or
/// over-budget extras (`e × t > worker_cores`, or a zero dimension)
/// dropped — those could never be placed on the worker pool anyway.
pub fn candidate_configs(worker_cores: usize, extras: &[(usize, usize)]) -> Vec<(usize, usize)> {
    let mut out = symmetric_configs(worker_cores);
    for &(e, t) in extras {
        if e == 0 || t == 0 || e * t > worker_cores {
            continue;
        }
        if !out.contains(&(e, t)) {
            out.push((e, t));
        }
    }
    out
}

/// The model-specific extra configurations §7.3 grants the search on top
/// of the symmetric splits, derived from the graph's parallelism profile:
/// 3×21 always (GoogleNet's 2–3 inception branches), 6×10 when the graph
/// is at least 6 wide (PathNet's 6 parallel modules). Shared by `graphi
/// profile`, `graphi autotune`, and the driver's auto-fleet path so all
/// three search the same candidate space.
pub fn model_extras(max_width: usize) -> Vec<(usize, usize)> {
    let mut extras = vec![(3, 21)];
    if max_width >= 6 {
        extras.push((6, 10));
    }
    extras
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knl() -> Machine {
        Machine::knl7250()
    }

    #[test]
    fn graphi_placement_is_tile_disjoint() {
        // the paper's 8×8 configuration
        let p = Placement::pinned_disjoint(&knl(), 8, 8).unwrap();
        assert_eq!(p.executors(), 8);
        assert!(!p.any_tile_sharing(), "§4.4: executors must not share L2 tiles");
        // every executor owns exactly 4 tiles (8 threads / 2 cores-per-tile)
        for e in 0..8 {
            assert_eq!(p.tiles_of(e).len(), 4);
        }
    }

    #[test]
    fn reserved_cores_for_scheduler_and_lightweight() {
        let p = Placement::pinned_disjoint(&knl(), 32, 2).unwrap();
        let sched = p.scheduler_core.unwrap();
        let lw = p.lightweight_core.unwrap();
        assert_ne!(sched, lw);
        for e in 0..p.executors() {
            assert!(!p.cores[e].contains(&sched));
            assert!(!p.cores[e].contains(&lw));
        }
    }

    #[test]
    fn capacity_enforced() {
        // 66 worker cores available on the 68-core part
        assert!(Placement::pinned_disjoint(&knl(), 33, 2).is_ok());
        let err = Placement::pinned_disjoint(&knl(), 64, 2).unwrap_err();
        assert!(matches!(err, PlacementError::NotEnoughCores { .. }));
    }

    #[test]
    fn odd_team_sizes_can_share_tiles_when_forced() {
        // pinned-shared placement with odd team size straddles tiles
        let p = Placement::pinned_shared_tiles(&knl(), 4, 3).unwrap();
        assert!(p.any_tile_sharing());
    }

    #[test]
    fn single_thread_executors_share_no_tiles_when_aligned() {
        // 1-thread executors at tile-aligned packing still share tiles
        // pairwise (two cores per tile) — the paper's §5.2 chooses *even*
        // team sizes precisely to avoid this.
        let p = Placement::pinned_disjoint(&knl(), 16, 1).unwrap();
        assert!(p.any_tile_sharing(), "odd teams inevitably share tiles");
        let p2 = Placement::pinned_disjoint(&knl(), 16, 2).unwrap();
        assert!(!p2.any_tile_sharing(), "even teams are tile-exclusive");
    }

    #[test]
    fn os_managed_assumes_sharing() {
        let p = Placement::os_managed(8);
        assert!(p.executors_share_tile(0, 7));
    }

    #[test]
    fn symmetric_config_enumeration() {
        let configs = symmetric_configs(64);
        assert!(configs.contains(&(1, 64)));
        assert!(configs.contains(&(8, 8)));
        assert!(configs.contains(&(64, 1)));
        assert_eq!(configs.len(), 7); // 1,2,4,8,16,32,64
        for &(k, t) in &configs {
            assert_eq!(k * t, 64);
        }
    }

    #[test]
    fn candidate_config_enumeration() {
        // extras are appended, deduplicated, and budget-checked
        let configs = candidate_configs(64, &[(6, 10), (3, 21), (8, 8), (0, 4), (4, 0), (64, 2)]);
        assert!(configs.contains(&(6, 10)));
        assert!(configs.contains(&(3, 21)));
        // (8,8) already symmetric — not duplicated
        assert_eq!(configs.iter().filter(|&&c| c == (8, 8)).count(), 1);
        // zero dims and over-budget (64×2 = 128 > 64) extras dropped
        assert!(!configs.iter().any(|&(e, t)| e == 0 || t == 0));
        assert!(!configs.contains(&(64, 2)));
        assert_eq!(configs.len(), 9); // 7 symmetric + 2 valid extras
        for &(e, t) in &configs {
            assert!(e * t <= 64);
        }
    }

    #[test]
    fn candidate_configs_without_extras_is_symmetric() {
        assert_eq!(candidate_configs(64, &[]), symmetric_configs(64));
    }

    #[test]
    fn model_extras_track_graph_width() {
        assert_eq!(model_extras(2), vec![(3, 21)]);
        assert_eq!(model_extras(6), vec![(3, 21), (6, 10)]);
        assert_eq!(model_extras(40), vec![(3, 21), (6, 10)]);
    }

    #[test]
    fn snc4_domain_spanning() {
        let snc = Machine::knl7250_snc4();
        // 17-core domains: an 8×8 packing puts executor 2 (cores 16..24)
        // across the domain-0/1 boundary
        let p = Placement::pinned_disjoint(&snc, 8, 8).unwrap();
        assert!(!p.executor_spans_domains(&snc, 0));
        assert!(p.executor_spans_domains(&snc, 2));
        // quadrant mode never spans
        let quad = Machine::knl7250();
        assert!(!p.executor_spans_domains(&quad, 2));
    }

    #[test]
    fn zero_args_rejected() {
        assert_eq!(Placement::pinned_disjoint(&knl(), 0, 4).unwrap_err(), PlacementError::ZeroExecutors);
        assert_eq!(Placement::pinned_disjoint(&knl(), 4, 0).unwrap_err(), PlacementError::ZeroTeam);
    }
}
