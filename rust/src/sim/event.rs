//! The virtual clock and event queue.
//!
//! Time is `f64` microseconds. Events are totally ordered by
//! `(time, sequence)` — the sequence number makes simultaneous events
//! deterministic (FIFO by insertion), which keeps every simulation run
//! exactly reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in microseconds.
pub type SimTime = f64;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A deterministic discrete-event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at`. Scheduling in the past
    /// (before `now`) is a logic error in the engine; we clamp and debug-
    /// assert rather than corrupt the clock.
    pub fn schedule(&mut self, at: SimTime, payload: E) {
        debug_assert!(at >= self.now - 1e-9, "scheduling into the past: {at} < {}", self.now);
        debug_assert!(at.is_finite(), "non-finite event time");
        let time = at.max(self.now);
        self.heap.push(Entry { time, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule at `now + delay`.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        self.schedule(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.payload)
        })
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(5.0, "c");
        q.schedule(1.0, "a");
        q.schedule(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_broken_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(2.0, 1);
        q.schedule(2.0, 2);
        q.schedule(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(4.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 4.0);
        q.schedule_in(2.0, ());
        assert_eq!(q.peek_time(), Some(6.0));
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "a");
        q.schedule(10.0, "z");
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (1.0, "a"));
        q.schedule(5.0, "m"); // after now, before z
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["m", "z"]);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1.0, ());
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
