//! Analytic operation cost model for the Intel Xeon Phi 7250.
//!
//! The paper's evaluation machine is unavailable, so every experiment runs
//! against this model + the discrete-event simulator in [`crate::sim`]
//! (see DESIGN.md §2 for the substitution argument). The model prices one
//! operation executed by a team of `k` threads:
//!
//! ```text
//! T(op, k) = dispatch + fork(k) + roofline(op) / speedup(op, k)
//! ```
//!
//! * `roofline(op)` — single-thread time = max(compute, memory) with
//!   class-specific efficiency (MKL GEMM, LIBXSMM conv, stream element-wise)
//! * `speedup(op, k)` — the Universal Scalability Law
//!   `S(k) = k / (1 + α(k−1) + β·k(k−1))`, whose contention (α) and
//!   coherence (β) coefficients are chosen per op class and size so the
//!   saturation points match the paper's Fig 2 (GEMM ≈ 8 threads,
//!   element-wise ≈ 16 on the reference sizes)
//! * `fork(k)` — OpenMP team fork/barrier cost, logarithmic in `k`
//!
//! Interference (unpinned threads, oversubscription, shared ready-queue
//! polling, L2 overlap) is priced by [`interference`] and applied by the
//! simulator, not baked into the base duration.

pub mod calibration;
pub mod interference;
pub mod machine;
pub mod model;

pub use calibration::Calibration;
pub use interference::Interference;
pub use machine::Machine;
pub use model::CostModel;
