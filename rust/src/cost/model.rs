//! The operation cost model.

use crate::graph::op::{OpClass, OpKind};

use super::calibration::Calibration;
use super::machine::Machine;

/// Prices operations on a [`Machine`] under a [`Calibration`].
#[derive(Debug, Clone)]
pub struct CostModel {
    pub machine: Machine,
    pub cal: Calibration,
}

impl CostModel {
    pub fn knl() -> CostModel {
        CostModel { machine: Machine::knl7250(), cal: Calibration::default() }
    }

    pub fn knl_deterministic() -> CostModel {
        CostModel { machine: Machine::knl7250(), cal: Calibration::deterministic() }
    }

    /// Single-thread roofline time of the op body, µs (no dispatch/fork).
    pub fn serial_body_us(&self, op: &OpKind) -> f64 {
        if matches!(op, OpKind::Scalar) {
            return self.cal.tiny_op_us;
        }
        let eff = self.efficiency(op);
        let compute_s = op.flops() / (self.machine.peak_core_flops() * eff);
        let memory_s = op.bytes() / self.machine.core_bw;
        compute_s.max(memory_s) * 1e6
    }

    /// Fraction-of-peak efficiency for the op's primitive library.
    pub fn efficiency(&self, op: &OpKind) -> f64 {
        match op.class() {
            OpClass::Gemm => self.cal.eff_gemm,
            OpClass::Conv => self.cal.eff_conv_libxsmm,
            OpClass::Elementwise => self.cal.eff_elementwise,
            OpClass::Memory => 1.0, // priced purely by bytes
            OpClass::Tiny => 1.0,
        }
    }

    /// Like [`Self::efficiency`] but with MKL's (slower) conv path — the
    /// TensorFlow baseline's primitive set (§7.2).
    pub fn efficiency_mkl(&self, op: &OpKind) -> f64 {
        match op.class() {
            OpClass::Conv => self.cal.eff_conv_mkl,
            _ => self.efficiency(op),
        }
    }

    /// "Work size" used to scale the saturation point: flops for compute
    /// classes, elements for memory-bound element-wise ops.
    fn work(&self, op: &OpKind) -> f64 {
        match op.class() {
            OpClass::Elementwise | OpClass::Memory => op.output_elems() as f64,
            _ => op.flops().max(1.0),
        }
    }

    /// Saturation thread count k*: where adding threads stops helping.
    /// Calibrated to Fig 2 at the reference sizes; grows sublinearly
    /// (`sat_growth_exp`) with work size.
    pub fn saturation(&self, op: &OpKind) -> f64 {
        let (sat_ref, work_ref) = match op.class() {
            OpClass::Gemm => (self.cal.sat_gemm_ref, self.cal.work_gemm_ref),
            OpClass::Conv => (self.cal.sat_conv_ref, self.cal.work_conv_ref),
            OpClass::Elementwise | OpClass::Memory => (self.cal.sat_ew_ref, self.cal.work_ew_ref),
            OpClass::Tiny => return 1.0,
        };
        let scale = (self.work(op) / work_ref).powf(self.cal.sat_growth_exp);
        (sat_ref * scale).clamp(1.0, 128.0)
    }

    fn alpha(&self, op: &OpKind) -> f64 {
        match op.class() {
            OpClass::Gemm => self.cal.alpha_gemm,
            OpClass::Conv => self.cal.alpha_conv,
            _ => self.cal.alpha_ew,
        }
    }

    /// Speedup of the op body on `k` threads: Amdahl-style contention up
    /// to the saturation point k*, a plateau beyond it, and a mild
    /// oversaturation penalty (per-thread work becomes too fine-grained).
    /// Fig 2 shows exactly this shape: near-linear growth, a knee at the
    /// saturation thread count, then a flat-to-slightly-declining tail.
    ///
    /// `S(k) = A(min(k,k*)) / (1 + γ·log2(max(1, k/k*)))`,
    /// `A(k) = k / (1 + α(k−1))`.
    pub fn speedup(&self, op: &OpKind, k: usize) -> f64 {
        if k <= 1 {
            return 1.0;
        }
        let k = k as f64;
        let alpha = self.alpha(op);
        let kstar = self.saturation(op);
        let keff = k.min(kstar);
        let amdahl = keff / (1.0 + alpha * (keff - 1.0));
        let over = (k / kstar).max(1.0).log2();
        amdahl / (1.0 + self.cal.oversat_penalty * over)
    }

    /// OpenMP fork/join cost for a warm pinned team, µs.
    pub fn fork_us(&self, k: usize) -> f64 {
        if k <= 1 {
            0.0
        } else {
            self.cal.fork_base_us + self.cal.fork_log_us * (k as f64).log2()
        }
    }

    /// Duration of `op` on a pinned `k`-thread executor with no
    /// interference, µs. This is the quantity Fig 2 plots (as FLOPS).
    pub fn duration_us(&self, op: &OpKind, k: usize) -> f64 {
        if matches!(op, OpKind::Scalar) || op.is_tiny() {
            // tiny ops are executed inline; team size is irrelevant
            return self.cal.tiny_op_us.max(self.serial_body_us(op).min(self.cal.tiny_op_us * 4.0));
        }
        self.cal.dispatch_us + self.fork_us(k) + self.serial_body_us(op) / self.speedup(op, k)
    }

    /// Duration of `op` on a **gang** of `width` executors, each a pinned
    /// `threads_per`-thread team, µs. The gang behaves as one fused
    /// `width × threads_per`-thread team, so the profiled scalar duration
    /// becomes a `f(width)` curve through the same USL speedup shape
    /// ([`Self::speedup`]): sublinear by default, with the Fig-2
    /// oversaturation tail once the fused team passes the op's saturation
    /// point — exactly why small ops should stay at width 1 and wide GEMMs
    /// should not. Gang *formation* latency (recruiting `width − 1` idle
    /// peers) is scheduler time, not op time; the simulator charges it to
    /// `scheduler_busy_us` via [`Calibration::gang_recruit_us`].
    pub fn gang_duration_us(&self, op: &OpKind, width: usize, threads_per: usize) -> f64 {
        self.duration_us(op, width.max(1) * threads_per.max(1))
    }

    /// Duration under the TensorFlow primitive set (MKL conv) — same
    /// formula, lower conv efficiency.
    pub fn duration_us_mkl(&self, op: &OpKind, k: usize) -> f64 {
        let d = self.duration_us(op, k);
        match op.class() {
            OpClass::Conv => {
                let ratio = self.cal.eff_conv_libxsmm / self.cal.eff_conv_mkl;
                // Only the compute part stretches; conv is compute-bound, so
                // scaling the body is accurate enough.
                self.cal.dispatch_us + self.fork_us(k) + (d - self.cal.dispatch_us - self.fork_us(k)) * ratio
            }
            _ => d,
        }
    }

    /// Achieved FLOPS of the op at team size `k` (for Fig 2/3 axes).
    pub fn flops_rate(&self, op: &OpKind, k: usize) -> f64 {
        op.flops() / (self.duration_us(op, k) * 1e-6)
    }

    /// Memory-bandwidth demand of the op while running on `k` threads,
    /// bytes/s. The simulator sums this across concurrently running ops and
    /// stretches memory-bound ops when the total exceeds MCDRAM bandwidth.
    pub fn bw_demand(&self, op: &OpKind, k: usize) -> f64 {
        let duration_s = self.duration_us(op, k) * 1e-6;
        if duration_s <= 0.0 {
            0.0
        } else {
            op.bytes() / duration_s
        }
    }

    /// Is the op memory-bound at team size `k`? (Memory roofline dominates.)
    pub fn memory_bound(&self, op: &OpKind, k: usize) -> bool {
        let eff = self.efficiency(op);
        let compute_s = op.flops() / (self.machine.peak_core_flops() * eff);
        let memory_s = op.bytes() / self.machine.core_bw;
        // Once threads exceed what memory can feed, the op is bandwidth-bound.
        memory_s > compute_s || self.machine.bw_for_cores(k) >= self.machine.mcdram_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::EwKind;

    fn model() -> CostModel {
        CostModel::knl_deterministic()
    }

    /// The paper's Fig 2a GEMM: [64,512]×[512,512].
    fn ref_gemm() -> OpKind {
        OpKind::MatMul { m: 64, k: 512, n: 512 }
    }

    /// The paper's Fig 2b element-wise multiply: 32 768 pairs.
    fn ref_ew() -> OpKind {
        OpKind::Elementwise { n: 32_768, arity: 2, kind: EwKind::Arith }
    }

    #[test]
    fn fig2a_gemm_saturates_near_8() {
        let m = model();
        let op = ref_gemm();
        let best_k = (1..=64usize)
            .max_by(|&a, &b| m.flops_rate(&op, a).total_cmp(&m.flops_rate(&op, b)))
            .unwrap();
        assert!(
            (6..=10).contains(&best_k),
            "GEMM saturation at {best_k}, paper says ≈8"
        );
    }

    #[test]
    fn fig2b_elementwise_saturates_near_16() {
        let m = model();
        let op = ref_ew();
        let best_k = (1..=64usize)
            .max_by(|&a, &b| m.flops_rate(&op, a).total_cmp(&m.flops_rate(&op, b)))
            .unwrap();
        assert!(
            (12..=20).contains(&best_k),
            "element-wise saturation at {best_k}, paper says ≈16"
        );
    }

    #[test]
    fn all_cores_on_one_small_op_wastes_most_of_the_chip() {
        // §3.2: running multiple small ops in parallel is >6× faster than
        // one small op on the whole chip. Check the per-op side: 64 threads
        // on the reference GEMM achieve far below 8× the single-thread rate.
        let m = model();
        let op = ref_gemm();
        let s64 = m.flops_rate(&op, 64) / m.flops_rate(&op, 1);
        assert!(s64 < 8.0, "64-thread speedup {s64} should be far below linear");
    }

    #[test]
    fn eight_parallel_gemms_beat_one_wide_gemm() {
        // The aggregate-throughput version of the §3.2 claim: 8 executors
        // of 8 threads each running 8 GEMMs vs. one 64-thread executor
        // running them one after another.
        let m = model();
        let op = ref_gemm();
        let parallel_time = m.duration_us(&op, 8); // 8 run simultaneously
        let sequential_time = 8.0 * m.duration_us(&op, 64);
        let gain = sequential_time / parallel_time;
        assert!(gain > 4.0, "parallel small-op gain {gain}, paper shows >6×");
    }

    #[test]
    fn duration_monotone_until_saturation() {
        let m = model();
        let op = ref_gemm();
        for k in 1..7usize {
            assert!(
                m.duration_us(&op, k + 1) < m.duration_us(&op, k),
                "duration should fall up to saturation (k={k})"
            );
        }
    }

    #[test]
    fn duration_degrades_past_saturation() {
        let m = model();
        let op = ref_ew();
        assert!(m.duration_us(&op, 64) > m.duration_us(&op, 16));
    }

    #[test]
    fn larger_gemms_saturate_later() {
        let m = model();
        let small = OpKind::MatMul { m: 64, k: 128, n: 128 };
        let large = OpKind::MatMul { m: 64, k: 1024, n: 1024 };
        assert!(m.saturation(&large) > m.saturation(&small));
    }

    #[test]
    fn mkl_conv_slower_than_libxsmm() {
        let m = model();
        let conv = OpKind::Conv2d { batch: 64, h: 32, w: 32, cin: 16, cout: 16, kernel: 3, stride: 1 };
        assert!(m.duration_us_mkl(&conv, 8) > 1.5 * m.duration_us(&conv, 8));
        // GEMM is unaffected (both use MKL GEMM)
        let g = ref_gemm();
        assert_eq!(m.duration_us_mkl(&g, 8), m.duration_us(&g, 8));
    }

    #[test]
    fn elementwise_is_memory_bound() {
        let m = model();
        assert!(m.memory_bound(&ref_ew(), 4));
        assert!(!m.memory_bound(&ref_gemm(), 1));
    }

    #[test]
    fn tiny_ops_cost_sub_microsecond_scale() {
        let m = model();
        let d = m.duration_us(&OpKind::Scalar, 32);
        assert!(d <= 3.0, "tiny op {d}µs");
    }

    #[test]
    fn gang_width_curves_are_sublinear_and_class_dependent() {
        let m = model();
        // width 1 is exactly the scalar pricing
        assert_eq!(m.gang_duration_us(&ref_gemm(), 1, 4), m.duration_us(&ref_gemm(), 4));
        // a wide GEMM (large work, late saturation) gains from width…
        let big = OpKind::MatMul { m: 512, k: 2048, n: 2048 };
        let d1 = m.gang_duration_us(&big, 1, 4);
        let d4 = m.gang_duration_us(&big, 4, 4);
        assert!(d4 < d1, "wide GEMM should gain from a width-4 gang: {d4} !< {d1}");
        // …but sublinearly (never the full 4×)
        assert!(d4 > d1 / 4.0, "gang speedup must be sublinear");
        // the small reference GEMM saturates near 8 threads, so width 4 of
        // 4-thread executors (16 fused) is already past the knee and loses
        let small1 = m.gang_duration_us(&ref_gemm(), 1, 8);
        let small4 = m.gang_duration_us(&ref_gemm(), 4, 8);
        assert!(small4 > small1, "oversaturated gang must not beat width 1");
        // tiny ops are width-oblivious
        assert_eq!(m.gang_duration_us(&OpKind::Scalar, 8, 4), m.duration_us(&OpKind::Scalar, 4));
    }

    #[test]
    fn fork_cost_grows_logarithmically() {
        let m = model();
        assert_eq!(m.fork_us(1), 0.0);
        let f8 = m.fork_us(8);
        let f64_ = m.fork_us(64);
        assert!(f64_ > f8);
        assert!(f64_ < 2.5 * f8, "log growth, not linear");
    }

    #[test]
    fn speedup_at_one_is_one() {
        let m = model();
        assert_eq!(m.speedup(&ref_gemm(), 1), 1.0);
    }

    #[test]
    fn bw_demand_positive_for_memory_ops() {
        let m = model();
        let d = m.bw_demand(&ref_ew(), 8);
        assert!(d > 1e9, "element-wise at speed should demand >1 GB/s, got {d}");
    }

    #[test]
    fn gemm_peak_rate_plausible_for_knl() {
        // MKL on KNL reaches hundreds of GFLOPS on medium GEMM with 8
        // threads; sanity-check we're in that regime (not 10× off).
        let m = model();
        let rate = m.flops_rate(&ref_gemm(), 8);
        assert!(
            (50e9..1000e9).contains(&rate),
            "8-thread GEMM rate {rate:.3e} outside plausible range"
        );
    }
}
