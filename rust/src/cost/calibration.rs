//! Calibration constants for the KNL cost model.
//!
//! Each constant is tied to a published observation — either a number the
//! paper reports directly (saturation points, pinning penalty, context
//! switch cost) or a well-known property of the hardware/libraries (MKL
//! efficiency, OpenMP fork cost). The unit tests in [`super::model`] assert
//! the *shapes* the paper measured hold under these constants; the
//! benchmark suite regenerates the corresponding figures.

/// All tunable constants in one place.
#[derive(Debug, Clone)]
pub struct Calibration {
    // -- dispatch & fork ---------------------------------------------------
    /// Fixed cost for an executor to pick up and launch one op, µs.
    pub dispatch_us: f64,
    /// OpenMP team fork/join base cost, µs (pinned threads, warm team).
    pub fork_base_us: f64,
    /// Additional fork/join cost per log2(team size), µs.
    pub fork_log_us: f64,
    /// Cost for a moldable-gang leader to recruit one parked/idle peer
    /// executor, µs: an eventcount notify plus the recruit's wake-up and
    /// gang-post handshake. Charged `(w−1)×` per formed gang into
    /// scheduler-busy time, which is what makes narrow small-op graphs
    /// prefer `w = 1` in the autotuner's width search.
    pub gang_recruit_us: f64,

    // -- single-thread efficiency (roofline ceilings) ----------------------
    /// MKL GEMM fraction-of-peak on one core at the paper's medium sizes.
    pub eff_gemm: f64,
    /// LIBXSMM small-conv fraction-of-peak (better than MKL conv: §7.2
    /// attributes part of the PathNet speedup to LIBXSMM primitives).
    pub eff_conv_libxsmm: f64,
    /// MKL-style direct conv fraction-of-peak (what the TensorFlow baseline
    /// uses for convolutions).
    pub eff_conv_mkl: f64,
    /// Element-wise compute efficiency (vectorized transcendental loop).
    pub eff_elementwise: f64,

    // -- Universal Scalability Law coefficients ----------------------------
    /// USL contention coefficient α per class at the reference work size.
    pub alpha_gemm: f64,
    pub alpha_conv: f64,
    pub alpha_ew: f64,
    /// Saturation thread-count k* at the reference work sizes.
    /// Fig 2: GEMM [64,512]×[512,512] saturates at 8, element-wise
    /// (32 768 pairs) at 16.
    pub sat_gemm_ref: f64,
    pub sat_conv_ref: f64,
    pub sat_ew_ref: f64,
    /// Reference work sizes (flops for compute classes, elements for ew).
    pub work_gemm_ref: f64,
    pub work_conv_ref: f64,
    pub work_ew_ref: f64,
    /// Exponent for how the saturation point grows with work size.
    pub sat_growth_exp: f64,
    /// Oversaturation penalty γ: fractional slowdown per doubling of
    /// threads past the saturation point (Fig 2 tails are flat-to-slightly
    /// declining, not retrograde).
    pub oversat_penalty: f64,

    // -- interference ------------------------------------------------------
    /// Slowdown weight for unpinned thread/core collisions; calibrated so
    /// OS-managed placement is up to ~45 % slower (Fig 3) at high
    /// occupancy.
    pub unpinned_collision_weight: f64,
    /// Extra slowdown per unit of oversubscription (threads/cores − 1):
    /// context-switch churn when more software threads than cores exist.
    pub oversub_weight: f64,
    /// Mean per-op migration stall for unpinned threads, µs.
    pub migration_mean_us: f64,
    /// Probability an unpinned op suffers a migration stall.
    pub migration_prob: f64,
    /// OpenMP thread-team reconfiguration cost, ms (paper §6 measures
    /// 10–30 ms; we use the midpoint).
    pub team_resize_ms: f64,
    /// Multiplier on op duration when two executors share an L2 tile.
    pub l2_overlap_factor: f64,

    // -- software queues ---------------------------------------------------
    /// Uncontended dequeue from a shared ready queue, µs.
    pub queue_base_us: f64,
    /// Additional dequeue cost per concurrent poller (CAS retries /
    /// cache-line bouncing), µs. Drives Table 2's naive-scheduler gap.
    pub queue_cas_us: f64,
    /// Unpark/wake-up latency of a pool thread that blocked on the empty
    /// shared queue (futex wake + context switch on the slow KNL cores).
    /// Graphi executors spin on private rings and never park (§4.4).
    pub baseline_wake_us: f64,
    /// Graphi per-dispatch scheduler decision cost (heap pop + bitmap scan
    /// + ring push), µs.
    pub graphi_dispatch_us: f64,
    /// Scheduler polling granularity, µs (busy-loop iteration).
    pub scheduler_poll_us: f64,

    // -- TensorFlow-like baseline ------------------------------------------
    /// Eigen splits element-wise ops into chunks of this many elements,
    /// each a job in a centralized queue (§7.2 discussion).
    pub eigen_chunk_elems: u64,
    /// Per-chunk enqueue/dequeue/execute overhead, µs.
    pub eigen_chunk_overhead_us: f64,

    // -- misc ---------------------------------------------------------------
    /// Cost of one tiny/bootstrap op on the light-weight executor, µs.
    pub tiny_op_us: f64,
    /// Stream-store saving on element-wise output write-backs (§6: slight
    /// improvement; fraction of output-write time saved).
    pub stream_store_saving: f64,
    /// SNC-4: multiplier on memory-bound op time when an executor's team
    /// spans NUMA domains (remote MCDRAM slice accesses).
    pub numa_span_penalty: f64,
    /// SNC-4: memory-latency improvement for domain-contained executors vs
    /// quadrant mode (the reason SNC exists; Intel reports single-digit %).
    pub numa_local_boost: f64,
    /// Extra cost of a *cross-domain* steal in decentralized dispatch, µs:
    /// the CAS and the first lines of the stolen op's inputs cross the
    /// mesh to another cluster's CHA/MCDRAM slice. Priced on top of
    /// `queue_base_us + queue_cas_us` so the autotuner sees why same-domain
    /// victims are preferred (SNC modes only; quadrant pays nothing).
    pub steal_cross_domain_us: f64,
    /// §6 cache-affinity: fraction of an element-wise op saved when it
    /// runs on the executor whose L2 still holds its input ("modest
    /// margin"; GEMMs see none).
    pub locality_ew_saving: f64,
    /// Log-normal σ of run-to-run duration noise (profiling variance).
    pub noise_sigma: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            dispatch_us: 1.5,
            fork_base_us: 0.4,
            fork_log_us: 0.5,
            gang_recruit_us: 0.7,

            eff_gemm: 0.62,
            eff_conv_libxsmm: 0.55,
            eff_conv_mkl: 0.35,
            eff_elementwise: 0.25,

            alpha_gemm: 0.08,
            alpha_conv: 0.03,
            alpha_ew: 0.04,
            sat_gemm_ref: 8.0,
            sat_conv_ref: 48.0,
            sat_ew_ref: 16.0,
            // GEMM ref: [64,512]×[512,512] = 33.55 MF (Fig 2a)
            work_gemm_ref: 2.0 * 64.0 * 512.0 * 512.0,
            // conv ref: PathNet-medium module ≈ 0.9 GF; LIBXSMM convs keep
            // scaling far past the Fig-2 GEMM knee on KNL
            work_conv_ref: 9.0e8,
            // element-wise ref: 32 768 elements (Fig 2b)
            work_ew_ref: 32_768.0,
            sat_growth_exp: 1.0 / 3.0,
            oversat_penalty: 0.06,

            unpinned_collision_weight: 0.62,
            oversub_weight: 1.2,
            migration_mean_us: 25.0,
            migration_prob: 0.25,
            team_resize_ms: 20.0,
            l2_overlap_factor: 1.18,

            queue_base_us: 0.25,
            queue_cas_us: 0.8,
            baseline_wake_us: 3.5,
            graphi_dispatch_us: 0.9,
            scheduler_poll_us: 0.5,

            eigen_chunk_elems: 4096,
            eigen_chunk_overhead_us: 1.2,

            tiny_op_us: 0.6,
            stream_store_saving: 0.25,
            numa_span_penalty: 1.22,
            numa_local_boost: 0.95,
            steal_cross_domain_us: 1.1,
            locality_ew_saving: 0.08,
            noise_sigma: 0.04,
        }
    }
}

impl Calibration {
    /// A noise-free variant for deterministic tests.
    pub fn deterministic() -> Calibration {
        Calibration { noise_sigma: 0.0, ..Calibration::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Calibration::default();
        assert!(c.eff_gemm > c.eff_conv_mkl);
        assert!(c.eff_conv_libxsmm > c.eff_conv_mkl, "LIBXSMM beats MKL conv (§7.2)");
        assert!(c.sat_ew_ref > c.sat_gemm_ref, "Fig 2: ew saturates later than this GEMM");
        assert!((0.0..1.0).contains(&c.stream_store_saving));
        assert!(c.team_resize_ms >= 10.0 && c.team_resize_ms <= 30.0, "paper §6 range");
        assert!(
            c.gang_recruit_us > 0.0 && c.gang_recruit_us < c.dispatch_us,
            "recruiting one peer must cost less than a full dispatch"
        );
    }
}
