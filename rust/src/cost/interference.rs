//! Interference pricing (§3 of the paper).
//!
//! The base cost model assumes pinned threads on exclusive cores. Real
//! engines deviate in exactly the ways the paper catalogues — unpinned
//! threads colliding on cores, oversubscribed thread pools, a contended
//! global ready-queue, executors sharing an L2 tile. [`Interference`]
//! prices those deviations so the simulator can apply them per engine.

use crate::util::rng::Rng;

use super::calibration::Calibration;

/// Interference pricing over a [`Calibration`].
#[derive(Debug, Clone)]
pub struct Interference {
    pub cal: Calibration,
}

impl Interference {
    pub fn new(cal: Calibration) -> Interference {
        Interference { cal }
    }

    /// Expected fraction of threads that share a physical core with some
    /// other runnable thread when the OS places `threads` uniformly at
    /// random over `cores` (birthday-style bound).
    pub fn collision_fraction(threads: usize, cores: usize) -> f64 {
        if threads <= 1 || cores == 0 {
            return 0.0;
        }
        let c = cores as f64;
        1.0 - ((c - 1.0) / c).powi(threads as i32 - 1)
    }

    /// Multiplicative slowdown for an op executed by *unpinned* (OS-managed)
    /// threads while `total_threads` runnable threads compete for `cores`.
    ///
    /// Deterministic part: collision + oversubscription weights, calibrated
    /// so that high-occupancy unpinned runs lose up to ~45 % vs pinned
    /// (Fig 3). `rng` adds migration stalls and placement luck.
    pub fn unpinned_factor(&self, total_threads: usize, cores: usize, rng: &mut Rng) -> f64 {
        let collision = Self::collision_fraction(total_threads, cores);
        let oversub = (total_threads as f64 / cores as f64 - 1.0).max(0.0);
        let mut factor =
            1.0 + self.cal.unpinned_collision_weight * collision + self.cal.oversub_weight * oversub;
        // Placement luck: some runs land well, some badly.
        factor *= rng.jitter(0.06);
        factor.max(1.0)
    }

    /// Extra latency (µs) an unpinned op may pay for a thread migration.
    pub fn migration_stall_us(&self, rng: &mut Rng) -> f64 {
        if rng.chance(self.cal.migration_prob) {
            rng.exponential(self.cal.migration_mean_us)
        } else {
            0.0
        }
    }

    /// Cost (µs) of one dequeue from a shared ready-queue with `pollers`
    /// concurrent idle executors spinning on it. This is the software
    /// contention the Graphi scheduler eliminates (§4.3, Table 2).
    pub fn shared_queue_dequeue_us(&self, pollers: usize) -> f64 {
        self.cal.queue_base_us + self.cal.queue_cas_us * pollers.saturating_sub(1) as f64
    }

    /// Wake-up latency for a parked baseline pool thread (§4.4: Graphi's
    /// spinning executors avoid this entirely).
    pub fn wake_latency_us(&self) -> f64 {
        self.cal.baseline_wake_us
    }

    /// Cost (µs) of the Graphi scheduler making one dispatch decision
    /// (max-heap pop, bitmap scan, SPSC ring push — uncontended by design).
    pub fn graphi_dispatch_us(&self) -> f64 {
        self.cal.graphi_dispatch_us
    }

    /// Multiplier when two executors' threads share an L2 tile (§4.4: Graphi
    /// places executors on disjoint tiles to avoid exactly this).
    pub fn l2_overlap_factor(&self, shares_tile: bool) -> f64 {
        if shares_tile {
            self.cal.l2_overlap_factor
        } else {
            1.0
        }
    }

    /// One-time cost (µs) of resizing an OpenMP thread team (§6: 10–30 ms;
    /// kills the dynamic-executor-count optimization).
    pub fn team_resize_us(&self) -> f64 {
        self.cal.team_resize_ms * 1e3
    }

    /// Duration noise factor (profiling variance; log-normal).
    pub fn noise(&self, rng: &mut Rng) -> f64 {
        if self.cal.noise_sigma == 0.0 {
            1.0
        } else {
            rng.jitter(self.cal.noise_sigma)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn interference() -> Interference {
        Interference::new(Calibration::deterministic())
    }

    #[test]
    fn collision_fraction_limits() {
        assert_eq!(Interference::collision_fraction(1, 68), 0.0);
        let f64t = Interference::collision_fraction(64, 68);
        assert!((0.5..0.8).contains(&f64t), "64 threads on 68 cores: {f64t}");
        let f4 = Interference::collision_fraction(4, 68);
        assert!(f4 < 0.06, "sparse occupancy nearly collision-free: {f4}");
    }

    #[test]
    fn fig3_unpinned_penalty_up_to_45_percent() {
        let i = interference();
        let mut rng = Rng::new(1);
        // full occupancy, no oversubscription: the Fig 3 regime
        let mut worst: f64 = 0.0;
        let mut sum = 0.0;
        let n = 1000;
        for _ in 0..n {
            let f = i.unpinned_factor(64, 68, &mut rng);
            worst = worst.max(f);
            sum += f;
        }
        let mean = sum / n as f64;
        assert!(
            (1.25..1.55).contains(&mean),
            "mean unpinned penalty {mean}, paper: up to 45 %"
        );
        assert!(worst < 1.8, "worst case bounded: {worst}");
    }

    #[test]
    fn oversubscription_makes_it_worse() {
        let i = interference();
        let mut a = Rng::new(2);
        let mut b = Rng::new(2);
        let normal = i.unpinned_factor(64, 68, &mut a);
        let oversub = i.unpinned_factor(136, 68, &mut b);
        assert!(oversub > normal + 0.5, "2× oversubscription: {oversub} vs {normal}");
    }

    #[test]
    fn queue_contention_scales_with_pollers() {
        let i = interference();
        let one = i.shared_queue_dequeue_us(1);
        let many = i.shared_queue_dequeue_us(32);
        assert!(one < 0.5);
        assert!(many > 10.0, "32 pollers should cost >10µs: {many}");
        assert!(i.graphi_dispatch_us() < one + i.cal.queue_cas_us * 4.0,
            "graphi dispatch must be cheaper than even lightly contended queue");
    }

    #[test]
    fn team_resize_in_paper_range() {
        let us = interference().team_resize_us();
        assert!((10_000.0..=30_000.0).contains(&us));
    }

    #[test]
    fn pinned_has_no_l2_penalty() {
        let i = interference();
        assert_eq!(i.l2_overlap_factor(false), 1.0);
        assert!(i.l2_overlap_factor(true) > 1.0);
    }

    #[test]
    fn deterministic_noise_is_identity() {
        let i = interference();
        let mut rng = Rng::new(3);
        assert_eq!(i.noise(&mut rng), 1.0);
    }

    #[test]
    fn migration_stalls_occasional() {
        let i = interference();
        let mut rng = Rng::new(4);
        let stalls: Vec<f64> = (0..1000).map(|_| i.migration_stall_us(&mut rng)).collect();
        let nonzero = stalls.iter().filter(|&&s| s > 0.0).count();
        // prob 0.25 → about a quarter
        assert!((150..350).contains(&nonzero), "nonzero stalls {nonzero}");
    }
}
