//! Machine description (§2 of the paper, Fig 1).

/// Hardware parameters of the simulated manycore CPU.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Human-readable name.
    pub name: &'static str,
    /// Physical cores (one hardware thread used per core, as in the paper).
    pub cores: usize,
    /// Cores per tile sharing an L2 slice (KNL: 2).
    pub cores_per_tile: usize,
    /// Clock frequency, Hz.
    pub freq_hz: f64,
    /// Peak single-precision flops per core per cycle
    /// (KNL: 2 VPUs × 16 SP lanes × 2 FMA = 64).
    pub flops_per_core_cycle: f64,
    /// Shared L2 per tile, bytes (KNL: 1 MiB).
    pub l2_per_tile: u64,
    /// L1 data cache per core, bytes.
    pub l1_per_core: u64,
    /// MCDRAM bandwidth, bytes/s (KNL: >400 GB/s; we use 420).
    pub mcdram_bw: f64,
    /// Single-core sustainable stream bandwidth, bytes/s. On KNL a core
    /// cannot saturate MCDRAM alone (~12 GB/s measured in the literature).
    pub core_bw: f64,
    /// DDR4 bandwidth, bytes/s (for footprints beyond 16 GB MCDRAM;
    /// unused by the paper's workloads, which fit MCDRAM).
    pub ddr_bw: f64,
    /// MCDRAM capacity, bytes.
    pub mcdram_capacity: u64,
    /// Sub-NUMA cluster domains. Quadrant mode behaves as one symmetric
    /// domain (the paper's configuration); SNC-4 exposes 4 domains with
    /// lower local latency but a cross-domain penalty (§2, §9 future work).
    pub numa_domains: usize,
}

impl Machine {
    /// The paper's testbed: Intel Xeon Phi processor 7250 ("Knights
    /// Landing"), quadrant cluster mode, one thread per core.
    pub fn knl7250() -> Machine {
        Machine {
            name: "Intel Xeon Phi 7250 (KNL, quadrant)",
            cores: 68,
            cores_per_tile: 2,
            freq_hz: 1.4e9,
            flops_per_core_cycle: 64.0,
            l2_per_tile: 1 << 20,
            l1_per_core: 32 << 10,
            mcdram_bw: 420e9,
            core_bw: 12e9,
            ddr_bw: 90e9,
            mcdram_capacity: 16 << 30,
            numa_domains: 1,
        }
    }

    /// KNL in SNC-4 sub-NUMA clustering mode (§9's "challenging memory
    /// hierarchies" future work): 4 domains of 17 cores, each with a local
    /// MCDRAM slice. Local accesses are slightly faster than quadrant
    /// mode; cross-domain accesses pay a penalty.
    pub fn knl7250_snc4() -> Machine {
        Machine { name: "Intel Xeon Phi 7250 (KNL, SNC-4)", numa_domains: 4, ..Machine::knl7250() }
    }

    /// A Skylake-like Xeon Platinum 8180 (the paper's §9 notes Graphi also
    /// wins there) — used by the generalization ablation.
    pub fn skylake8180() -> Machine {
        Machine {
            name: "Intel Xeon Platinum 8180 (Skylake-SP)",
            cores: 28,
            cores_per_tile: 1, // private L2 per core on SKX
            freq_hz: 2.5e9,
            flops_per_core_cycle: 64.0, // 2×AVX-512 FMA
            l2_per_tile: 1 << 20,
            l1_per_core: 32 << 10,
            mcdram_bw: 120e9, // 6-channel DDR4
            core_bw: 15e9,
            ddr_bw: 120e9,
            mcdram_capacity: 64 << 30,
            numa_domains: 1,
        }
    }

    /// Peak single-precision flops of one core, flops/s.
    pub fn peak_core_flops(&self) -> f64 {
        self.freq_hz * self.flops_per_core_cycle
    }

    /// Peak single-precision flops of the whole chip, flops/s.
    pub fn peak_chip_flops(&self) -> f64 {
        self.peak_core_flops() * self.cores as f64
    }

    /// Number of tiles.
    pub fn tiles(&self) -> usize {
        self.cores / self.cores_per_tile
    }

    /// Aggregate stream bandwidth achievable by `k` cores: linear in `k`
    /// until the MCDRAM limit.
    pub fn bw_for_cores(&self, k: usize) -> f64 {
        (self.core_bw * k as f64).min(self.mcdram_bw)
    }

    /// NUMA domain of a physical core (cores are striped contiguously).
    pub fn domain_of_core(&self, core: usize) -> usize {
        if self.numa_domains <= 1 {
            0
        } else {
            core / self.cores.div_ceil(self.numa_domains)
        }
    }

    /// Executor→NUMA-domain map for an `executors × threads_per` fleet
    /// whose teams are packed contiguously over the worker cores (the
    /// placement [`crate::sim::topology::Placement::pinned_disjoint`]
    /// produces, modulo tile rounding). Each executor is assigned the
    /// domain of its team's *first* core — the home of its deque and the
    /// hot end of its working set — which is what the decentralized
    /// runtime's victim ranking cares about
    /// ([`crate::engine::worksteal::DomainMap`]). Quadrant mode (one
    /// domain) maps every executor to domain 0.
    pub fn executor_domain_map(&self, executors: usize, threads_per: usize) -> Vec<u32> {
        let last = self.cores.saturating_sub(1);
        (0..executors)
            .map(|e| self.domain_of_core((e * threads_per.max(1)).min(last)) as u32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knl_peak_is_about_6tf() {
        let m = Machine::knl7250();
        let peak = m.peak_chip_flops();
        // 68 × 1.4 GHz × 64 = 6.09 TF
        assert!((peak - 6.0928e12).abs() < 1e9, "peak {peak}");
        assert_eq!(m.tiles(), 34);
    }

    #[test]
    fn bandwidth_caps_at_mcdram() {
        let m = Machine::knl7250();
        assert_eq!(m.bw_for_cores(1), 12e9);
        assert_eq!(m.bw_for_cores(68), 420e9); // 816 GB/s demand capped
    }

    #[test]
    fn snc4_domains() {
        let m = Machine::knl7250_snc4();
        assert_eq!(m.numa_domains, 4);
        assert_eq!(m.domain_of_core(0), 0);
        assert_eq!(m.domain_of_core(16), 0);
        assert_eq!(m.domain_of_core(17), 1);
        assert_eq!(m.domain_of_core(67), 3);
        // quadrant mode is a single domain
        assert_eq!(Machine::knl7250().domain_of_core(67), 0);
    }

    #[test]
    fn executor_domain_map_tracks_fleet_shape() {
        // SNC-4 on the 68-core part: 17-core domains. An 8×8 fleet packs
        // executor e at cores [8e, 8e+8): executors 0–1 in domain 0,
        // 2 straddles (home core 16 → domain 0), 3–4 in domain 1, …
        let snc = Machine::knl7250_snc4();
        let map = snc.executor_domain_map(8, 8);
        assert_eq!(map.len(), 8);
        assert_eq!(map[0], 0);
        assert_eq!(map[1], 0);
        assert_eq!(map[2], 0, "home core 16 is still domain 0");
        assert_eq!(map[3], 1);
        assert_eq!(map[7], 3);
        // quadrant mode: everything is one domain
        assert!(Machine::knl7250().executor_domain_map(8, 8).iter().all(|&d| d == 0));
        // a 2-domain part (34-core domains): home cores 0/16/32/48
        let two = Machine { numa_domains: 2, ..Machine::knl7250() };
        assert_eq!(two.executor_domain_map(4, 16), vec![0, 0, 0, 1]);
        // degenerate inputs stay in bounds
        assert_eq!(two.executor_domain_map(3, 0), vec![0, 0, 0]);
        assert_eq!(two.executor_domain_map(2, 1000), vec![0, 1]);
    }

    #[test]
    fn skylake_has_private_l2() {
        let m = Machine::skylake8180();
        assert_eq!(m.tiles(), 28);
        assert_eq!(m.cores_per_tile, 1);
    }
}
