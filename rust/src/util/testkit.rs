//! Property-based testing helpers (the image has no `proptest`).
//!
//! [`check`] runs a property over `cases` generated inputs; on failure it
//! performs greedy shrinking via the generator's [`Gen::shrink`] hook and
//! reports the minimal counterexample with the seed needed to replay it.
//!
//! Generators are plain structs implementing [`Gen`]; combinators cover the
//! shapes Graphi's invariants need (sized vectors, ranges, random DAGs).

use crate::util::rng::Rng;

/// A generator of values of type `T` with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    /// Generate a value from entropy.
    fn generate(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate smaller values; default: no shrinking.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` against `cases` generated values. Panics with the minimal
/// failing case (after greedy shrinking) and the replay seed.
pub fn check<G: Gen>(name: &str, gen: &G, cases: usize, prop: impl Fn(&G::Value) -> Result<(), String>) {
    let seed = std::env::var("GRAPHI_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first failing shrink candidate.
            let mut smallest = value;
            let mut msg = first_msg;
            let mut budget = 1000;
            'outer: while budget > 0 {
                for candidate in gen.shrink(&smallest) {
                    budget -= 1;
                    if let Err(m) = prop(&candidate) {
                        smallest = candidate;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property `{name}` failed on case {case} (seed {seed}, \
                 set GRAPHI_TEST_SEED to replay):\n  value: {smallest:?}\n  error: {msg}"
            );
        }
    }
}

/// Uniform usize in `[lo, hi]`, shrinking toward `lo`.
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.0, self.1 + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in `[lo, hi)`, shrinking toward lo.
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;

    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.uniform(self.0, self.1)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.0 {
            vec![self.0, self.0 + (*v - self.0) / 2.0]
        } else {
            Vec::new()
        }
    }
}

/// Vector of values from an inner generator, length in `[min_len, max_len]`.
/// Shrinks by halving length, then element-wise.
pub struct VecOf<G: Gen> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.range(self.min_len, self.max_len + 1);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let keep = (v.len() / 2).max(self.min_len);
            out.push(v[..keep].to_vec());
            let mut minus_one = v.clone();
            minus_one.pop();
            out.push(minus_one);
        }
        // shrink the first shrinkable element
        for (i, item) in v.iter().enumerate() {
            let candidates = self.inner.shrink(item);
            if let Some(c) = candidates.into_iter().next() {
                let mut copy = v.clone();
                copy[i] = c;
                out.push(copy);
                break;
            }
        }
        out
    }
}

/// A random DAG description: `n` nodes, edge list with `src < dst`
/// (guaranteeing acyclicity), and per-node weights in `[0.5, wmax)`.
/// This is the workhorse generator for scheduler/graph invariants.
#[derive(Debug, Clone, PartialEq)]
pub struct DagCase {
    pub n: usize,
    pub edges: Vec<(u32, u32)>,
    pub weights: Vec<f64>,
}

pub struct DagGen {
    pub max_nodes: usize,
    pub edge_prob: f64,
    pub wmax: f64,
}

impl Default for DagGen {
    fn default() -> Self {
        DagGen { max_nodes: 40, edge_prob: 0.15, wmax: 100.0 }
    }
}

impl Gen for DagGen {
    type Value = DagCase;

    fn generate(&self, rng: &mut Rng) -> DagCase {
        let n = rng.range(1, self.max_nodes + 1);
        let mut edges = Vec::new();
        for dst in 1..n as u32 {
            // ensure weak connectivity pressure: bias one random upstream edge
            if rng.chance(0.8) {
                let src = rng.below(dst as u64) as u32;
                edges.push((src, dst));
            }
            for src in 0..dst {
                if rng.chance(self.edge_prob) {
                    edges.push((src, dst));
                }
            }
        }
        edges.sort_unstable();
        edges.dedup();
        let weights = (0..n).map(|_| rng.uniform(0.5, self.wmax)).collect();
        DagCase { n, edges, weights }
    }

    fn shrink(&self, v: &DagCase) -> Vec<DagCase> {
        let mut out = Vec::new();
        // drop the last node (and its edges)
        if v.n > 1 {
            let n = v.n - 1;
            let edges: Vec<_> = v
                .edges
                .iter()
                .copied()
                .filter(|&(a, b)| (a as usize) < n && (b as usize) < n)
                .collect();
            out.push(DagCase { n, edges, weights: v.weights[..n].to_vec() });
        }
        // drop half the edges
        if v.edges.len() > 1 {
            out.push(DagCase {
                n: v.n,
                edges: v.edges[..v.edges.len() / 2].to_vec(),
                weights: v.weights.clone(),
            });
        }
        // drop a single edge
        if !v.edges.is_empty() {
            let mut edges = v.edges.clone();
            edges.pop();
            out.push(DagCase { n: v.n, edges, weights: v.weights.clone() });
        }
        out
    }
}

/// Deterministic per-session fault injection for the fault-tolerance
/// suites and `graphi serve --fault-rate`.
///
/// A plan names at most one fault for a session: an op that panics, an op
/// that dawdles (sleeps before completing — the watchdog/deadline
/// stressor), or a client-side cancel delay. Plans are drawn from a
/// seeded [`Rng`], so every fault schedule is replayable; [`wrap`]
/// applies the op-level faults around an inner work closure, while the
/// cancel component is the *client's* job (call
/// `SessionHandle::cancel` after [`FaultPlan::cancel_after_us`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// This node's op panics (message tagged [`FaultPlan::PANIC_TAG`]).
    pub panic_at: Option<u32>,
    /// This node's op sleeps for `(node, µs)` before completing.
    pub delay_at: Option<(u32, f64)>,
    /// The submitting client should cancel the session after this many µs.
    pub cancel_after_us: Option<f64>,
}

impl FaultPlan {
    /// Marker in every injected panic message, so harnesses can tell an
    /// injected fault from a real bug when asserting on payloads.
    pub const PANIC_TAG: &'static str = "injected fault";

    /// Draw a plan: with probability `rate` the session gets exactly one
    /// fault, split evenly between an op panic, an op delay of
    /// `delay_us`, and a client cancel after `delay_us`.
    pub fn draw(rng: &mut Rng, nodes: usize, rate: f64, delay_us: f64) -> FaultPlan {
        let mut plan = FaultPlan::default();
        if nodes == 0 || !rng.chance(rate) {
            return plan;
        }
        let node = rng.below(nodes as u64) as u32;
        match rng.below(3) {
            0 => plan.panic_at = Some(node),
            1 => plan.delay_at = Some((node, delay_us)),
            _ => plan.cancel_after_us = Some(delay_us),
        }
        plan
    }

    /// Does this plan inject anything at all?
    pub fn is_faulty(&self) -> bool {
        self.panic_at.is_some() || self.delay_at.is_some() || self.cancel_after_us.is_some()
    }

    /// Wrap `inner` with this plan's op-level faults: the delay node
    /// sleeps, the panic node panics (after any delay), every other node
    /// just runs `inner`.
    pub fn wrap<F>(self, inner: F) -> impl Fn(u32) + Send + Sync
    where
        F: Fn(u32) + Send + Sync,
    {
        move |n: u32| {
            if let Some((d, us)) = self.delay_at {
                if n == d {
                    std::thread::sleep(std::time::Duration::from_micros(us as u64));
                }
            }
            if self.panic_at == Some(n) {
                panic!("{} at node {n}", FaultPlan::PANIC_TAG);
            }
            inner(n);
        }
    }

    /// [`wrap`](FaultPlan::wrap) for moldable `(node, rank, width)` work
    /// closures. The panic fires on the gang's **highest rank**
    /// (`width − 1`, i.e. the last recruit — rank 0 when the gang shrank
    /// to the leader alone), because a member panic exercises the
    /// member→`fail_session` confinement path that a leader panic does
    /// not. The delay sleeps on rank 0 only, so a gang dawdles once, not
    /// `width` times.
    pub fn wrap_wide<F>(self, inner: F) -> impl Fn(u32, u32, u32) + Send + Sync
    where
        F: Fn(u32, u32, u32) + Send + Sync,
    {
        move |n: u32, rank: u32, width: u32| {
            if let Some((d, us)) = self.delay_at {
                if n == d && rank == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(us as u64));
                }
            }
            if self.panic_at == Some(n) && rank + 1 == width.max(1) {
                panic!("{} at node {n} (rank {rank} of {width})", FaultPlan::PANIC_TAG);
            }
            inner(n, rank, width);
        }
    }
}

/// A seeded overload scenario for the stress/chaos suites: an **arrival
/// burst × tight deadlines × one op panic**. Half the sessions arrive at
/// t = 0 (the burst), the rest trail in at `gap_us` spacing; every
/// session shares one tight deadline (used as both admission patience
/// and execution deadline); exactly one session's op panics and a
/// sprinkle of clients cancel — so a single scenario can populate all
/// five outcome classes (completed / failed / cancelled /
/// deadline_missed / shed) that the conservation assertions sum.
#[derive(Debug, Clone)]
pub struct OverloadPlan {
    /// Per-session arrival offset, µs from the scenario start.
    pub arrive_us: Vec<u64>,
    /// Deadline shared by every session, µs.
    pub deadline_us: u64,
    /// Per-session op-level faults (exactly one panic plan among them).
    pub plans: Vec<FaultPlan>,
}

impl OverloadPlan {
    /// Draw a scenario: `sessions` requests over graphs of `nodes` ops,
    /// trailing arrivals spaced ~`gap_us`, everyone under `deadline_us`.
    pub fn draw(
        rng: &mut Rng,
        sessions: usize,
        nodes: usize,
        gap_us: u64,
        deadline_us: u64,
    ) -> OverloadPlan {
        assert!(sessions >= 1 && nodes >= 1 && deadline_us >= 1);
        let burst = (sessions / 2).max(1);
        let mut arrive_us = Vec::with_capacity(sessions);
        for i in 0..sessions {
            if i < burst {
                arrive_us.push(0);
            } else {
                arrive_us.push((i - burst + 1) as u64 * gap_us + rng.below(gap_us.max(1)));
            }
        }
        let panicker = rng.below(sessions as u64) as usize;
        let mut plans = vec![FaultPlan::default(); sessions];
        plans[panicker].panic_at = Some(rng.below(nodes as u64) as u32);
        for (i, plan) in plans.iter_mut().enumerate() {
            if i != panicker && rng.chance(0.2) {
                plan.cancel_after_us = Some(rng.uniform(0.0, deadline_us as f64));
            }
        }
        OverloadPlan { arrive_us, deadline_us, plans }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("trivially true", &UsizeRange(0, 10), 50, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics() {
        check("always fails", &UsizeRange(0, 10), 10, |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_finds_boundary() {
        // Property: v < 7. Failing values shrink toward 7.
        let result = std::panic::catch_unwind(|| {
            check("lt7", &UsizeRange(0, 100), 100, |v| {
                if *v < 7 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 7"))
                }
            });
        });
        let panic_msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        // greedy shrink should reach a smallish failing value; at minimum
        // it must report *some* failing value >= 7 and <= initial
        assert!(panic_msg.contains("value:"), "{panic_msg}");
    }

    #[test]
    fn dag_gen_produces_valid_dags() {
        let gen = DagGen::default();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let case = gen.generate(&mut rng);
            assert_eq!(case.weights.len(), case.n);
            for &(a, b) in &case.edges {
                assert!(a < b, "edge {a}->{b} not topologically ordered");
                assert!((b as usize) < case.n);
            }
        }
    }

    #[test]
    fn dag_shrinks_preserve_invariant() {
        let gen = DagGen::default();
        let mut rng = Rng::new(2);
        let case = gen.generate(&mut rng);
        for c in gen.shrink(&case) {
            for &(a, b) in &c.edges {
                assert!(a < b && (b as usize) < c.n);
            }
            assert_eq!(c.weights.len(), c.n);
        }
    }

    #[test]
    fn overload_plan_is_a_burst_with_one_panic() {
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let plan = OverloadPlan::draw(&mut rng, 12, 20, 500, 2_000);
            assert_eq!(plan.arrive_us.len(), 12);
            assert_eq!(plan.plans.len(), 12);
            assert_eq!(plan.deadline_us, 2_000);
            // half the sessions arrive as a burst at t = 0
            assert_eq!(plan.arrive_us.iter().filter(|&&t| t == 0).count(), 6);
            // trailing arrivals are strictly increasing past the burst
            assert!(plan.arrive_us[6..].windows(2).all(|w| w[0] < w[1]));
            assert!(plan.arrive_us[6..].iter().all(|&t| t >= 500));
            // exactly one panic plan; cancels never co-located with it
            let panics: Vec<_> = plan.plans.iter().filter(|p| p.panic_at.is_some()).collect();
            assert_eq!(panics.len(), 1);
            assert!(panics[0].cancel_after_us.is_none());
            assert!(plan.plans.iter().all(|p| p.delay_at.is_none()));
        }
    }

    #[test]
    fn wrap_wide_faults_the_highest_rank_only() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits = AtomicUsize::new(0);
        let plan = FaultPlan { panic_at: Some(3), ..Default::default() };
        let work = plan.wrap_wide(|_n, _rank, _w| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        // Healthy node: every seat of the gang runs the inner closure.
        for rank in 0..4 {
            work(1, rank, 4);
        }
        // Fault node: ranks below width − 1 still run...
        for rank in 0..3 {
            work(3, rank, 4);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 7);
        // ...and the last recruit panics with the tagged message.
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| work(3, 3, 4)))
            .expect_err("rank width-1 at the fault node must panic");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains(FaultPlan::PANIC_TAG), "{msg}");
        // Width-1 gangs degenerate to rank 0 panicking, matching `wrap`.
        let solo = FaultPlan { panic_at: Some(0), ..Default::default() }
            .wrap_wide(|_, _, _| {});
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| solo(0, 0, 1))).is_err());
    }

    #[test]
    fn vec_gen_length_bounds() {
        let gen = VecOf { inner: UsizeRange(0, 5), min_len: 2, max_len: 9 };
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let v = gen.generate(&mut rng);
            assert!((2..=9).contains(&v.len()));
        }
    }
}
