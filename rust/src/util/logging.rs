//! Tiny leveled stderr logger.
//!
//! Controlled by `GRAPHI_LOG` (`error|warn|info|debug|trace`, default
//! `info`). Macros are zero-cost when the level is filtered out beyond the
//! level check.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init() -> u8 {
    let level = match std::env::var("GRAPHI_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(level, Ordering::Relaxed);
    level
}

/// Current level (lazily initialized from the environment).
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == 255 {
        init()
    } else {
        l
    }
}

/// Override the level programmatically (used by `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:5}] {}", l.name(), args);
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn macros_compile() {
        set_level(Level::Error);
        log_info!("hidden {}", 1);
        log_error!("visible {}", 2);
        set_level(Level::Info);
    }
}
