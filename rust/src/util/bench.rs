//! Measurement harness (the image has no `criterion`).
//!
//! `cargo bench` targets are `harness = false` binaries that build a
//! [`BenchRunner`], register closures, and get warmup, repeated sampling,
//! outlier-robust summaries, and both human-readable and CSV output. The
//! same runner backs `graphi bench <figure>` in the CLI so every paper
//! table/figure can be regenerated either way.

use std::time::Instant;

use crate::util::stats::Summary;

/// Configuration for one run of the harness.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup iterations (discarded).
    pub warmup: usize,
    /// Measured samples.
    pub samples: usize,
    /// Lower bound on total measurement time per benchmark; the runner
    /// keeps sampling past `samples` until this much time has elapsed.
    pub min_time_s: f64,
    /// Emit a CSV file next to the text report (if `Some(path)`).
    pub csv_path: Option<String>,
    /// Quiet mode: suppress per-sample progress.
    pub quiet: bool,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 3, samples: 10, min_time_s: 0.2, csv_path: None, quiet: true }
    }
}

impl BenchConfig {
    /// Honors `GRAPHI_BENCH_FAST=1` for CI-speed runs.
    pub fn from_env() -> BenchConfig {
        let mut cfg = BenchConfig::default();
        if std::env::var("GRAPHI_BENCH_FAST").as_deref() == Ok("1") {
            cfg.warmup = 1;
            cfg.samples = 3;
            cfg.min_time_s = 0.0;
        }
        cfg
    }
}

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Extra key=value labels (model, size, executors …) for CSV output.
    pub labels: Vec<(String, String)>,
    /// Sample summary in microseconds.
    pub summary: Summary,
    /// Optional derived metric, e.g. GFLOPS, with a unit label.
    pub metric: Option<(f64, &'static str)>,
}

/// The harness.
pub struct BenchRunner {
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
    group: String,
}

impl BenchRunner {
    pub fn new(group: &str) -> BenchRunner {
        BenchRunner { config: BenchConfig::from_env(), results: Vec::new(), group: group.to_string() }
    }

    pub fn with_config(group: &str, config: BenchConfig) -> BenchRunner {
        BenchRunner { config, results: Vec::new(), group: group.to_string() }
    }

    /// Measure `f`, which returns a value that must not be optimized away.
    pub fn bench<T>(&mut self, name: &str, labels: &[(&str, String)], mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.config.warmup {
            std::hint::black_box(f());
        }
        let mut samples_us = Vec::with_capacity(self.config.samples);
        let started = Instant::now();
        while samples_us.len() < self.config.samples
            || started.elapsed().as_secs_f64() < self.config.min_time_s
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_us.push(t0.elapsed().as_secs_f64() * 1e6);
            if samples_us.len() >= self.config.samples * 100 {
                break; // safety valve for very fast bodies
            }
        }
        let summary = Summary::from_samples(&samples_us);
        self.results.push(BenchResult {
            name: name.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            summary,
            metric: None,
        });
        self.results.last().unwrap()
    }

    /// Record an externally computed result (e.g. a simulated makespan,
    /// where wall time is meaningless and the metric *is* the model output).
    pub fn record(&mut self, name: &str, labels: &[(&str, String)], value_us: f64) {
        self.record_with_metric(name, labels, value_us, None);
    }

    /// `record` with a derived metric such as GFLOPS.
    pub fn record_with_metric(
        &mut self,
        name: &str,
        labels: &[(&str, String)],
        value_us: f64,
        metric: Option<(f64, &'static str)>,
    ) {
        self.results.push(BenchResult {
            name: name.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            summary: Summary::from_samples(&[value_us]),
            metric,
        });
    }

    /// Attach a metric to the most recent result.
    pub fn set_metric(&mut self, value: f64, unit: &'static str) {
        if let Some(last) = self.results.last_mut() {
            last.metric = Some((value, unit));
        }
    }

    /// Render the text report.
    pub fn report(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "== bench group: {} ==", self.group);
        let name_w = self
            .results
            .iter()
            .map(|r| r.name.len())
            .max()
            .unwrap_or(4)
            .max(4);
        let _ = writeln!(
            out,
            "{:name_w$}  {:>12} {:>12} {:>12} {:>10}",
            "name", "mean", "p50", "max", "metric"
        );
        for r in &self.results {
            let metric = match r.metric {
                Some((v, unit)) => format!("{v:.2} {unit}"),
                None => "-".to_string(),
            };
            let _ = writeln!(
                out,
                "{:name_w$}  {:>12} {:>12} {:>12} {:>10}",
                r.name,
                crate::util::fmt_us(r.summary.mean),
                crate::util::fmt_us(r.summary.p50),
                crate::util::fmt_us(r.summary.max),
                metric,
            );
        }
        out
    }

    /// Render CSV (one row per result, labels flattened as columns).
    pub fn csv(&self) -> String {
        use std::fmt::Write;
        // union of label keys, stable order of first appearance
        let mut keys: Vec<String> = Vec::new();
        for r in &self.results {
            for (k, _) in &r.labels {
                if !keys.contains(k) {
                    keys.push(k.clone());
                }
            }
        }
        let mut out = String::from("group,name");
        for k in &keys {
            let _ = write!(out, ",{k}");
        }
        out.push_str(",mean_us,std_us,p50_us,p99_us,n,metric,metric_unit\n");
        for r in &self.results {
            let _ = write!(out, "{},{}", self.group, r.name);
            for k in &keys {
                let v = r
                    .labels
                    .iter()
                    .find(|(lk, _)| lk == k)
                    .map(|(_, lv)| lv.as_str())
                    .unwrap_or("");
                let _ = write!(out, ",{v}");
            }
            let (mv, mu) = r.metric.map(|(v, u)| (format!("{v}"), u)).unwrap_or_default();
            let _ = writeln!(
                out,
                ",{:.3},{:.3},{:.3},{:.3},{},{},{}",
                r.summary.mean, r.summary.std, r.summary.p50, r.summary.p99, r.summary.n, mv, mu
            );
        }
        out
    }

    /// Print the report and write CSV if configured. Call at the end of a
    /// bench main().
    pub fn finish(&self) {
        print!("{}", self.report());
        if let Some(path) = &self.config.csv_path {
            if let Some(parent) = std::path::Path::new(path).parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            match std::fs::write(path, self.csv()) {
                Ok(()) => println!("csv written to {path}"),
                Err(e) => eprintln!("failed to write {path}: {e}"),
            }
        }
    }

    /// The bench-group name this runner was constructed with.
    pub fn group(&self) -> &str {
        &self.group
    }
}

/// Merge a finished runner's results into the repo-root perf-trajectory
/// file (`../BENCH_scheduler.json` relative to the `rust/` package root;
/// override with `GRAPHI_BENCH_JSON`). Appends one entry —
/// `{bench, unix_time_s, fast_mode, results, <headlines…>}` — to the
/// file's `runs` array so successive runs from every bench target
/// accumulate a single trajectory. `headlines` are run-level scalar
/// summaries (e.g. a speedup-vs-legacy ratio) callers derive from their
/// own results.
pub fn merge_into_bench_json(runner: &BenchRunner, headlines: &[(&str, f64)]) {
    let path = std::env::var("GRAPHI_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_scheduler.json".to_string());
    merge_into_bench_json_at(runner, headlines, &path);
}

/// [`merge_into_bench_json`] with an explicit target path (no environment
/// access — also what tests use, to avoid `set_var` races).
pub fn merge_into_bench_json_at(runner: &BenchRunner, headlines: &[(&str, f64)], path: &str) {
    use crate::util::json::{self, Json};
    let mut run = Json::obj();
    run.set("bench", runner.group());
    run.set(
        "unix_time_s",
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0),
    );
    run.set("fast_mode", std::env::var("GRAPHI_BENCH_FAST").as_deref() == Ok("1"));
    let mut results = Vec::new();
    for r in &runner.results {
        let mut obj = Json::obj();
        obj.set("name", r.name.as_str());
        obj.set("mean_us", r.summary.mean);
        obj.set("p50_us", r.summary.p50);
        obj.set("samples", r.summary.n as f64);
        if let Some((v, unit)) = r.metric {
            obj.set("metric", v);
            obj.set("metric_unit", unit);
        }
        results.push(obj);
    }
    run.set("results", Json::Arr(results));
    for &(key, value) in headlines {
        run.set(key, value);
    }

    let mut doc = match std::fs::read_to_string(path).ok().and_then(|t| json::parse(&t).ok()) {
        Some(existing @ Json::Obj(_)) => existing,
        _ => {
            let mut d = Json::obj();
            d.set("group", runner.group());
            d.set(
                "note",
                "perf trajectory of the scheduler + profiler hot paths; regenerate with \
                 `cargo bench --bench scheduler_hotpath` / `--bench profiler_autotune` \
                 (GRAPHI_BENCH_FAST=1 for a smoke run)",
            );
            d.set("runs", Json::Arr(Vec::new()));
            d
        }
    };
    let mut runs = match doc.get("runs") {
        Some(Json::Arr(rs)) => rs.clone(),
        _ => Vec::new(),
    };
    runs.push(run);
    doc.set("runs", Json::Arr(runs));

    match std::fs::write(path, doc.to_string_pretty()) {
        Ok(()) => println!("bench json merged into {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// Convenience: label vector builder.
#[macro_export]
macro_rules! labels {
    ($($k:expr => $v:expr),* $(,)?) => {
        vec![$(($k, format!("{}", $v))),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut r = BenchRunner::with_config(
            "t",
            BenchConfig { warmup: 1, samples: 3, min_time_s: 0.0, csv_path: None, quiet: true },
        );
        r.bench("spin", &[], || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(r.results.len(), 1);
        assert!(r.results[0].summary.mean > 0.0);
    }

    #[test]
    fn record_and_csv() {
        let mut r = BenchRunner::with_config("g", BenchConfig::default());
        r.record("a", &[("model", "lstm".into()), ("k", "8".into())], 123.0);
        r.record_with_metric("b", &[("model", "pathnet".into())], 456.0, Some((1.5, "GFLOPS")));
        let csv = r.csv();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "group,name,model,k,mean_us,std_us,p50_us,p99_us,n,metric,metric_unit"
        );
        assert!(csv.contains("g,a,lstm,8,123.000"));
        assert!(csv.contains("GFLOPS"));
        let report = r.report();
        assert!(report.contains("bench group: g"));
    }

    #[test]
    fn labels_macro() {
        let l: Vec<(&str, String)> = labels! {"model" => "lstm", "k" => 8};
        assert_eq!(l[1], ("k", "8".to_string()));
    }

    #[test]
    fn bench_json_merge_appends_tagged_runs() {
        let path = std::env::temp_dir()
            .join(format!("graphi-bench-merge-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let path_s = path.display().to_string();
        let mut r = BenchRunner::with_config("merge_test", BenchConfig::default());
        r.record("alpha", &[], 10.0);
        r.set_metric(4.0, "ops/µs");
        merge_into_bench_json_at(&r, &[("headline_ratio", 2.5)], &path_s);
        merge_into_bench_json_at(&r, &[], &path_s);
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let runs = doc.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("bench").unwrap().as_str().unwrap(), "merge_test");
        assert_eq!(runs[0].get("headline_ratio").unwrap().as_f64().unwrap(), 2.5);
        let results = runs[1].get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "alpha");
        std::fs::remove_file(&path).unwrap();
    }
}
