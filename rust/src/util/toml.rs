//! Parser for the TOML subset used by `configs/*.toml`.
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean / flat-array values, `#` comments, blank lines. This is
//! deliberately not a general TOML implementation — just enough for Graphi
//! experiment configs, with precise error messages.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: `section -> key -> value`. Keys before any section
/// header land in the `""` section.
#[derive(Debug, Clone, Default)]
pub struct Document {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key)?.as_int()
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_float()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a document.
pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(ParseError {
                line: line_no,
                message: "unterminated section header".into(),
            })?;
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or(ParseError {
            line: line_no,
            message: format!("expected `key = value`, got `{line}`"),
        })?;
        let key = line[..eq].trim();
        let value_text = line[eq + 1..].trim();
        if key.is_empty() {
            return Err(ParseError { line: line_no, message: "empty key".into() });
        }
        let value = parse_value(value_text).map_err(|message| ParseError { line: line_no, message })?;
        doc.sections
            .entry(section.clone())
            .or_default()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\n", "\n").replace("\\\"", "\"")));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{text}`"))
}

/// Split a flat array body on commas that are outside quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
title = "lstm medium"

[model]
name = "lstm"
size = "medium"
layers = 4
batch = 64

[engine]
kind = "graphi"
executors = 8
threads_per_executor = 8
pin = true
noise = 0.05
configs = [2, 4, 8, 16, 32]
tags = ["a", "b"]
"#;

    #[test]
    fn parse_sample() {
        let doc = parse(SAMPLE).unwrap();
        assert_eq!(doc.get_str("", "title").unwrap(), "lstm medium");
        assert_eq!(doc.get_str("model", "name").unwrap(), "lstm");
        assert_eq!(doc.get_int("model", "layers").unwrap(), 4);
        assert_eq!(doc.get_bool("engine", "pin").unwrap(), true);
        assert_eq!(doc.get_float("engine", "noise").unwrap(), 0.05);
        let configs = doc.get("engine", "configs").unwrap().as_array().unwrap();
        assert_eq!(configs.len(), 5);
        assert_eq!(configs[2].as_int().unwrap(), 8);
        let tags = doc.get("engine", "tags").unwrap().as_array().unwrap();
        assert_eq!(tags[1].as_str().unwrap(), "b");
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.get_float("", "x").unwrap(), 3.0);
    }

    #[test]
    fn comments_inside_strings_preserved() {
        let doc = parse(r##"x = "a # b" # trailing"##).unwrap();
        assert_eq!(doc.get_str("", "x").unwrap(), "a # b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn bad_values_rejected() {
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("x = what").is_err());
        assert!(parse("[unterminated").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = parse("x = []").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_array().unwrap().len(), 0);
    }
}
