//! Minimal `anyhow`-style dynamic error type (the offline build image
//! ships no `anyhow`, so the slice of it Graphi uses is implemented
//! here: a boxed dynamic error, `.context()` / `.with_context()` on
//! `Result` and `Option`, `bail!` / `ensure!` macros, and `downcast_ref`
//! for cooperative errors like `CliError::Help`).
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error` itself — that is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on any
//! concrete error type) coherent.

use std::fmt;

/// A boxed dynamic error with a display-oriented API.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

/// Plain-string error payload (what `bail!`/`context` produce).
struct Message(String);

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Message {}

impl Error {
    /// Wrap a concrete error value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(err: E) -> Error {
        Error(Box::new(err))
    }

    /// Build an error from a message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error(Box::new(Message(msg.into())))
    }

    /// Downcast to a concrete error type, if that is what this wraps.
    pub fn downcast_ref<E: std::error::Error + 'static>(&self) -> Option<&E> {
        self.0.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (mirrors `anyhow::Context`).
pub trait Context<T> {
    /// Replace the error with `context: original`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`] but lazy.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/graphi")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let err = r.context("doing a thing").unwrap_err();
        assert!(format!("{err}").starts_with("doing a thing: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(format!("{err}"), "missing value");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                crate::bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
    }

    #[test]
    fn downcast_misses_other_types() {
        let err = Error::msg("plain");
        assert!(err.downcast_ref::<std::io::Error>().is_none());
    }
}
