//! Minimal JSON support (the image has no `serde`).
//!
//! Covers what Graphi needs: writing reports and Chrome trace files, and
//! reading back small config/result documents in tests. Numbers are f64;
//! object key order is preserved (insertion order) so emitted documents are
//! deterministic and diffable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(entries) => {
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = value.into();
                } else {
                    entries.push((key.to_string(), value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !entries.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}
impl From<BTreeMap<String, f64>> for Json {
    fn from(m: BTreeMap<String, f64>) -> Json {
        Json::Obj(m.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> ParseError {
        ParseError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            entries.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our documents.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("bad utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut doc = Json::obj();
        doc.set("name", "lstm").set("size", 512u64).set("ok", true);
        doc.set("times", Json::Arr(vec![1.5.into(), 2.5.into()]));
        let text = doc.to_string_compact();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(parse("0").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn reject_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let doc = Json::Str("tab\t \"quote\" back\\slash \n".to_string());
        let back = parse(&doc.to_string_compact()).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn nan_renders_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn pretty_output_parses() {
        let mut doc = Json::obj();
        doc.set("xs", Json::Arr(vec![1u64.into(), 2u64.into()]));
        let back = parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(back, doc);
    }
}
