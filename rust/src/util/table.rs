//! Aligned text tables for experiment reports (paper-style rows).

/// A simple column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with a separator under the header; first column left-aligned,
    /// the rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                if i == 0 {
                    line.push_str(&format!("{:<width$}", cell, width = widths[i]));
                } else {
                    line.push_str(&format!("{:>width$}", cell, width = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-markdown table (for EXPERIMENTS.md snippets).
    pub fn render_markdown(&self) -> String {
        let mut out = String::from("| ");
        out.push_str(&self.header.join(" | "));
        out.push_str(" |\n|");
        for _ in &self.header {
            out.push_str("---|");
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str("| ");
            out.push_str(&row.join(" | "));
            out.push_str(" |\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "time", "speedup"]);
        t.row_strs(&["lstm", "1.23ms", "2.1x"]);
        t.row_strs(&["googlenet-large", "45.6ms", "9.5x"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert_eq!(lines[0].len(), lines[2].len().max(lines[0].len()));
        assert!(lines[3].starts_with("googlenet-large"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["1", "2"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| a | b |\n|---|---|\n| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        Table::new(&["a"]).row_strs(&["1", "2"]);
    }
}
