//! Declarative command-line parsing (the image has no `clap`).
//!
//! A [`Spec`] describes flags and positionals for one subcommand; `parse`
//! matches `argv` against it, producing a [`Matches`] bag with typed
//! accessors, auto-generated `--help`, and did-you-mean suggestions on
//! unknown flags.

use std::collections::BTreeMap;

/// Description of one option (`--name value` or boolean `--name`).
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub boolean: bool,
}

/// Specification for a subcommand.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
    pub positionals: Vec<(&'static str, &'static str)>, // (name, help)
}

impl Spec {
    pub fn new(name: &'static str, about: &'static str) -> Spec {
        Spec { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    /// Add a value-taking option with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Spec {
        self.opts.push(Opt { name, help, default, boolean: false });
        self
    }

    /// Add a boolean flag (present/absent).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Spec {
        self.opts.push(Opt { name, help, default: None, boolean: true });
        self
    }

    /// Add a required positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Spec {
        self.positionals.push((name, help));
        self
    }

    /// Render a help screen.
    pub fn help(&self) -> String {
        let mut out = format!("graphi {} — {}\n\nUSAGE:\n  graphi {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            out.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            out.push_str(" [OPTIONS]");
        }
        out.push('\n');
        if !self.positionals.is_empty() {
            out.push_str("\nARGS:\n");
            for (p, help) in &self.positionals {
                out.push_str(&format!("  <{p}>  {help}\n"));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            let width = self.opts.iter().map(|o| o.name.len()).max().unwrap_or(0);
            for o in &self.opts {
                let default = match o.default {
                    Some(d) => format!(" [default: {d}]"),
                    None => String::new(),
                };
                let value = if o.boolean { "      " } else { " <VAL>" };
                out.push_str(&format!(
                    "  --{:width$}{value}  {}{default}\n",
                    o.name, o.help,
                ));
            }
        }
        out
    }

    /// Parse `args` (not including the program/subcommand names).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut explicit: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        for o in &self.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(CliError::Help(self.help()));
            }
            if let Some(name) = arg.strip_prefix("--") {
                // --name=value form
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let opt = self.opts.iter().find(|o| o.name == name).ok_or_else(|| {
                    CliError::UnknownFlag {
                        flag: name.to_string(),
                        suggestion: self.suggest(name),
                        help: self.help(),
                    }
                })?;
                if opt.boolean {
                    if inline.is_some() {
                        return Err(CliError::Other(format!("flag --{name} takes no value")));
                    }
                    flags.push(name.to_string());
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::Other(format!("--{name} requires a value")))?,
                    };
                    explicit.insert(name.to_string());
                    values.insert(name.to_string(), value);
                }
            } else {
                positionals.push(arg.clone());
            }
        }
        if positionals.len() < self.positionals.len() {
            let missing = self.positionals[positionals.len()].0;
            return Err(CliError::Other(format!(
                "missing required argument <{missing}>\n\n{}",
                self.help()
            )));
        }
        Ok(Matches { values, explicit, flags, positionals })
    }

    fn suggest(&self, unknown: &str) -> Option<String> {
        self.opts
            .iter()
            .map(|o| (edit_distance(unknown, o.name), o.name))
            .filter(|(d, _)| *d <= 2)
            .min_by_key(|(d, _)| *d)
            .map(|(_, n)| n.to_string())
    }
}

/// Parse outcome.
#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    explicit: std::collections::BTreeSet<String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Was this option given on the command line (as opposed to filled in
    /// from its spec default)? Lets callers give precedence to a config
    /// file over *defaulted* flags while still letting explicit flags win.
    pub fn is_explicit(&self, name: &str) -> bool {
        self.explicit.contains(name)
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        self.parse_as(name)
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        self.parse_as(name)
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        self.parse_as(name)
    }

    fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(text) => text.parse::<T>().map(Some).map_err(|_| {
                CliError::Other(format!("--{name}: cannot parse `{text}`"))
            }),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

/// CLI errors; `Help` is the cooperative `--help` exit.
#[derive(Debug)]
pub enum CliError {
    Help(String),
    UnknownFlag { flag: String, suggestion: Option<String>, help: String },
    Other(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Help(h) => write!(f, "{h}"),
            CliError::UnknownFlag { flag, suggestion, help } => {
                let hint = suggestion
                    .as_ref()
                    .map(|s| format!(" (did you mean --{s}?)"))
                    .unwrap_or_default();
                write!(f, "unknown flag --{flag}{hint}\n\n{help}")
            }
            CliError::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for CliError {}

/// Levenshtein distance (small strings; O(nm) fine).
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let cost = if ca == cb { 0 } else { 1 };
            cur[j + 1] = (prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("run", "run one experiment")
            .opt("model", Some("lstm"), "model name")
            .opt("executors", None, "number of executors")
            .flag("verbose", "chatty output")
            .positional("config", "config file")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let m = spec().parse(&args(&["cfg.toml"])).unwrap();
        assert_eq!(m.get("model").unwrap(), "lstm");
        assert_eq!(m.positional(0).unwrap(), "cfg.toml");
        let m = spec()
            .parse(&args(&["--model", "pathnet", "cfg.toml"]))
            .unwrap();
        assert_eq!(m.get("model").unwrap(), "pathnet");
    }

    #[test]
    fn equals_form() {
        let m = spec().parse(&args(&["--executors=16", "c"])).unwrap();
        assert_eq!(m.get_usize("executors").unwrap(), Some(16));
    }

    #[test]
    fn explicit_flags_distinguished_from_defaults() {
        let m = spec().parse(&args(&["cfg.toml"])).unwrap();
        assert!(!m.is_explicit("model"), "defaulted value is not explicit");
        let m = spec().parse(&args(&["--model", "pathnet", "cfg.toml"])).unwrap();
        assert!(m.is_explicit("model"));
        let m = spec().parse(&args(&["--model=pathnet", "cfg.toml"])).unwrap();
        assert!(m.is_explicit("model"), "--name=value form is explicit too");
    }

    #[test]
    fn boolean_flags() {
        let m = spec().parse(&args(&["--verbose", "c"])).unwrap();
        assert!(m.flag("verbose"));
        assert!(!m.flag("quiet"));
    }

    #[test]
    fn unknown_flag_suggests() {
        let err = spec().parse(&args(&["--modell", "x", "c"])).unwrap_err();
        match err {
            CliError::UnknownFlag { suggestion, .. } => {
                assert_eq!(suggestion.as_deref(), Some("model"));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn missing_positional_errors() {
        assert!(matches!(spec().parse(&[]), Err(CliError::Other(_))));
    }

    #[test]
    fn missing_value_errors() {
        assert!(spec().parse(&args(&["--executors"])).is_err());
    }

    #[test]
    fn help_requested() {
        assert!(matches!(
            spec().parse(&args(&["--help"])),
            Err(CliError::Help(_))
        ));
    }

    #[test]
    fn bad_number_reported() {
        let m = spec().parse(&args(&["--executors", "many", "c"])).unwrap();
        assert!(m.get_usize("executors").is_err());
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
    }
}
