//! Infrastructure substrates.
//!
//! The offline build image ships neither `clap`, `criterion`, `serde`,
//! `rand` nor `proptest`, so the small slices of each that Graphi needs are
//! implemented here from scratch:
//!
//! * [`error`]    — `anyhow`-style boxed dynamic error + context traits
//! * [`rng`]      — deterministic xorshift/splitmix PRNG + distributions
//! * [`stats`]    — running statistics, percentiles, confidence intervals
//! * [`json`]     — minimal JSON value model, writer and parser
//! * [`toml`]     — parser for the TOML subset used by `configs/*.toml`
//! * [`cli`]      — declarative command-line parser (clap replacement)
//! * [`bench`]    — measurement harness (criterion replacement)
//! * [`testkit`]  — property-based testing helpers (proptest replacement)
//! * [`logging`]  — leveled stderr logger
//! * [`table`]    — aligned text-table rendering for reports

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod table;
pub mod testkit;
pub mod toml;

/// Format a duration given in microseconds with a human-friendly unit.
pub fn fmt_us(us: f64) -> String {
    if us < 1e3 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Format a raw operation count (flops, bytes) with SI prefixes.
pub fn fmt_si(x: f64) -> String {
    const UNITS: &[(f64, &str)] = &[(1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")];
    for &(scale, suffix) in UNITS {
        if x >= scale {
            return format!("{:.2}{}", x / scale, suffix);
        }
    }
    format!("{x:.0}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_us_scales() {
        assert_eq!(fmt_us(12.34), "12.3µs");
        assert_eq!(fmt_us(12_340.0), "12.34ms");
        assert_eq!(fmt_us(12_340_000.0), "12.340s");
    }

    #[test]
    fn fmt_si_scales() {
        assert_eq!(fmt_si(999.0), "999");
        assert_eq!(fmt_si(1_500.0), "1.50K");
        assert_eq!(fmt_si(2.5e9), "2.50G");
        assert_eq!(fmt_si(3.2e12), "3.20T");
    }
}
