//! Summary statistics used by the profiler, the bench harness and the
//! report writers.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the ~95 % confidence interval on the mean.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 { 0.0 } else { 1.96 * self.std() / (self.n as f64).sqrt() }
    }
}

/// Full-sample summary with percentiles; used by the bench harness.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::from_samples on empty slice");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &s in samples {
            w.push(s);
        }
        Summary {
            n: samples.len(),
            mean: w.mean(),
            std: w.std(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }

    /// [`from_samples`](Self::from_samples) for possibly-empty input:
    /// `None` instead of a panic. Telemetry snapshots and per-outcome-class
    /// latency reports use this for classes that saw no sessions.
    pub fn from_samples_opt(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() { None } else { Some(Summary::from_samples(samples)) }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean; useful for speedup aggregation across models.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_basics() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample std of this classic set is ~2.138
        assert!((w.std() - 2.138089935).abs() < 1e-6);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_empty_is_nan() {
        let w = Welford::new();
        assert!(w.mean().is_nan());
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 4.0);
        assert!((percentile_sorted(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_of_constant() {
        let s = Summary::from_samples(&[3.0; 10]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p99, 3.0);
    }

    #[test]
    fn summary_opt_handles_degenerate_inputs() {
        // empty class → no summary, no panic
        assert!(Summary::from_samples_opt(&[]).is_none());
        // single sample → every percentile is that sample, all finite
        let s = Summary::from_samples_opt(&[42.0]).unwrap();
        assert_eq!(s.n, 1);
        assert_eq!(s.p50, 42.0);
        assert_eq!(s.p99, 42.0);
        assert!(s.mean.is_finite() && s.std.is_finite());
        // all-identical samples → zero spread, finite percentiles
        let s = Summary::from_samples_opt(&[7.0; 100]).unwrap();
        assert_eq!(s.p50, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std, 0.0);
        assert!(s.p50.is_finite() && s.p99.is_finite());
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn ci95_shrinks_with_n() {
        let mut a = Welford::new();
        let mut b = Welford::new();
        for i in 0..10 {
            a.push(i as f64);
        }
        for i in 0..1000 {
            b.push((i % 10) as f64);
        }
        assert!(b.ci95() < a.ci95());
    }
}
