//! Deterministic pseudo-random number generation.
//!
//! Everything in Graphi that needs randomness (simulated OS scheduling
//! noise, profiling jitter, property-test case generation, synthetic
//! corpora) goes through [`Rng`], a splitmix64-seeded xoshiro256++
//! generator. Determinism matters: simulator runs must be exactly
//! reproducible from a seed so that experiments and regression tests are
//! stable across machines.

/// xoshiro256++ PRNG seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-executor noise etc.).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and fine
    /// for our volumes).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std, truncated to be non-negative.
    pub fn normal_pos(&mut self, mean: f64, std: f64) -> f64 {
        (mean + std * self.normal()).max(0.0)
    }

    /// Log-normal multiplicative jitter around 1.0 with geometric std
    /// `sigma` (e.g. 0.05 ≈ ±5 % run-to-run variation).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // avoid ln(0)
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            // each bucket should be near 10_000; allow wide slack
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn jitter_centers_on_one() {
        let mut r = Rng::new(5);
        let mean: f64 = (0..10_000).map(|_| r.jitter(0.05)).sum::<f64>() / 10_000.0;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
