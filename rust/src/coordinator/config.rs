//! Typed experiment configuration.
//!
//! Loadable from the TOML subset in [`crate::util::toml`] (see
//! `configs/*.toml` for examples) or built programmatically / from CLI
//! flags. Every field has a sensible default so minimal configs stay
//! minimal.

use crate::engine::policies::Policy;
use crate::engine::{DispatchMode, PhasePlan, WidthPlan};
use crate::models::{ModelKind, ModelSize};
use crate::sim::topology::PlacementKind;
use crate::util::toml;

/// Which engine to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineChoice {
    Graphi,
    Sequential,
    Naive,
    TensorFlowLike,
}

impl EngineChoice {
    pub fn name(self) -> &'static str {
        match self {
            EngineChoice::Graphi => "graphi",
            EngineChoice::Sequential => "sequential",
            EngineChoice::Naive => "naive",
            EngineChoice::TensorFlowLike => "tensorflow",
        }
    }

    pub fn parse(s: &str) -> Option<EngineChoice> {
        match s.to_ascii_lowercase().as_str() {
            "graphi" => Some(EngineChoice::Graphi),
            "sequential" | "seq" => Some(EngineChoice::Sequential),
            "naive" => Some(EngineChoice::Naive),
            "tensorflow" | "tf" | "tensorflow-like" => Some(EngineChoice::TensorFlowLike),
            _ => None,
        }
    }
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub title: String,
    pub model: ModelKind,
    pub size: ModelSize,
    pub engine: EngineChoice,
    /// Executors × threads; `None` lets the profiler pick (§4.2).
    pub executors: Option<usize>,
    pub threads_per: Option<usize>,
    pub policy: Policy,
    pub placement: PlacementKind,
    /// Completion-resolution architecture of the Graphi engine
    /// (centralized scheduler vs executor-side resolution + stealing).
    /// `None` means "not pinned": the driver falls back to the paper's
    /// centralized design, and `graphi run --tuning` may adopt the
    /// artifact's winning mode. A flag or config-file value pins it.
    pub dispatch: Option<DispatchMode>,
    /// Per-phase dispatch plan, adopted from a tuning artifact by
    /// `graphi run --tuning` (an explicit `--dispatch` flag pins a uniform
    /// mode and drops it). Ignored with a warning when it does not line up
    /// with the graph's phase structure.
    pub phase_plan: Option<PhasePlan>,
    /// Per-op-class gang-width plan (moldable ops), adopted from a tuning
    /// artifact by `graphi run --tuning --widths`. `None` = every op runs
    /// at width 1.
    pub width_plan: Option<WidthPlan>,
    /// Batch-training iterations to simulate.
    pub iterations: usize,
    pub seed: u64,
    /// Profiler iterations when auto-configuring.
    pub profile_iterations: usize,
    /// Profiled per-op durations (µs) to derive the Graphi engine's level
    /// values from — loaded from a tuning artifact by `graphi run
    /// --tuning`. Ignored (with a warning) when it does not cover the
    /// graph.
    pub profiled_durations: Option<Vec<f64>>,
    /// Emit a Chrome trace of the last iteration to this path.
    pub trace_path: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            title: String::from("experiment"),
            model: ModelKind::Lstm,
            size: ModelSize::Medium,
            engine: EngineChoice::Graphi,
            executors: None,
            threads_per: None,
            policy: Policy::CriticalPathFirst,
            placement: PlacementKind::PinnedDisjoint,
            dispatch: None,
            phase_plan: None,
            width_plan: None,
            iterations: 5,
            seed: 42,
            profile_iterations: 3,
            profiled_durations: None,
            trace_path: None,
        }
    }
}

/// Config errors.
#[derive(Debug)]
pub enum ConfigError {
    Toml(toml::ParseError),
    Io(std::io::Error),
    BadValue { key: &'static str, value: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Toml(e) => write!(f, "config parse error: {e}"),
            ConfigError::Io(e) => write!(f, "io error reading config: {e}"),
            ConfigError::BadValue { key, value } => write!(f, "bad value for `{key}`: {value}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<toml::ParseError> for ConfigError {
    fn from(e: toml::ParseError) -> ConfigError {
        ConfigError::Toml(e)
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> ConfigError {
        ConfigError::Io(e)
    }
}

fn bad(key: &'static str, value: impl std::fmt::Display) -> ConfigError {
    ConfigError::BadValue { key, value: value.to_string() }
}

impl ExperimentConfig {
    /// Load from a TOML file.
    pub fn from_file(path: &str) -> Result<ExperimentConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text. Recognized keys:
    ///
    /// ```toml
    /// title = "..."
    /// [model]
    /// name = "lstm"           # lstm|phasedlstm|pathnet|googlenet|mlp
    /// size = "medium"         # small|medium|large
    /// [engine]
    /// kind = "graphi"         # graphi|sequential|naive|tensorflow
    /// executors = 8           # omit for profiler auto-pick
    /// threads_per_executor = 8
    /// policy = "cp-first"
    /// placement = "pinned"    # pinned|shared-tiles|os
    /// dispatch = "centralized" # centralized|decentralized
    /// [run]
    /// iterations = 5
    /// seed = 42
    /// profile_iterations = 3
    /// trace = "out/trace.json"
    /// ```
    pub fn from_toml(text: &str) -> Result<ExperimentConfig, ConfigError> {
        let doc = toml::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(t) = doc.get_str("", "title") {
            cfg.title = t.to_string();
        }
        if let Some(name) = doc.get_str("model", "name") {
            cfg.model = ModelKind::parse(name).ok_or_else(|| bad("model.name", name))?;
        }
        if let Some(size) = doc.get_str("model", "size") {
            cfg.size = ModelSize::parse(size).ok_or_else(|| bad("model.size", size))?;
        }
        if let Some(kind) = doc.get_str("engine", "kind") {
            cfg.engine = EngineChoice::parse(kind).ok_or_else(|| bad("engine.kind", kind))?;
        }
        if let Some(e) = doc.get_int("engine", "executors") {
            cfg.executors = Some(usize::try_from(e).map_err(|_| bad("engine.executors", e))?);
        }
        if let Some(t) = doc.get_int("engine", "threads_per_executor") {
            cfg.threads_per = Some(usize::try_from(t).map_err(|_| bad("engine.threads_per_executor", t))?);
        }
        if let Some(p) = doc.get_str("engine", "policy") {
            cfg.policy = Policy::parse(p).ok_or_else(|| bad("engine.policy", p))?;
        }
        if let Some(p) = doc.get_str("engine", "placement") {
            cfg.placement = match p {
                "pinned" => PlacementKind::PinnedDisjoint,
                "shared-tiles" => PlacementKind::PinnedSharedTiles,
                "os" | "unpinned" => PlacementKind::OsManaged,
                other => return Err(bad("engine.placement", other)),
            };
        }
        if let Some(d) = doc.get_str("engine", "dispatch") {
            cfg.dispatch = Some(DispatchMode::parse(d).ok_or_else(|| bad("engine.dispatch", d))?);
        }
        if let Some(i) = doc.get_int("run", "iterations") {
            cfg.iterations = usize::try_from(i).map_err(|_| bad("run.iterations", i))?;
        }
        if let Some(s) = doc.get_int("run", "seed") {
            cfg.seed = s as u64;
        }
        if let Some(i) = doc.get_int("run", "profile_iterations") {
            cfg.profile_iterations = usize::try_from(i).map_err(|_| bad("run.profile_iterations", i))?;
        }
        if let Some(t) = doc.get_str("run", "trace") {
            cfg.trace_path = Some(t.to_string());
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_toml_uses_defaults() {
        let cfg = ExperimentConfig::from_toml("title = \"t\"").unwrap();
        assert_eq!(cfg.model, ModelKind::Lstm);
        assert_eq!(cfg.engine, EngineChoice::Graphi);
        assert_eq!(cfg.iterations, 5);
    }

    #[test]
    fn full_toml_parses() {
        let text = r#"
title = "pathnet sweep"
[model]
name = "pathnet"
size = "large"
[engine]
kind = "naive"
executors = 6
threads_per_executor = 10
policy = "fifo"
placement = "os"
[run]
iterations = 7
seed = 9
trace = "out/t.json"
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.model, ModelKind::PathNet);
        assert_eq!(cfg.size, ModelSize::Large);
        assert_eq!(cfg.engine, EngineChoice::Naive);
        assert_eq!(cfg.executors, Some(6));
        assert_eq!(cfg.threads_per, Some(10));
        assert_eq!(cfg.policy, Policy::Fifo);
        assert_eq!(cfg.placement, PlacementKind::OsManaged);
        assert_eq!(cfg.iterations, 7);
        assert_eq!(cfg.trace_path.as_deref(), Some("out/t.json"));
    }

    #[test]
    fn bad_values_rejected() {
        assert!(ExperimentConfig::from_toml("[model]\nname = \"resnet\"").is_err());
        assert!(ExperimentConfig::from_toml("[engine]\nkind = \"cuda\"").is_err());
        assert!(ExperimentConfig::from_toml("[engine]\nplacement = \"moon\"").is_err());
        assert!(ExperimentConfig::from_toml("[engine]\ndispatch = \"psychic\"").is_err());
    }

    #[test]
    fn dispatch_mode_parses_and_defaults_unpinned() {
        let cfg = ExperimentConfig::from_toml("title = \"t\"").unwrap();
        assert_eq!(cfg.dispatch, None, "absent key must not pin a mode");
        let cfg =
            ExperimentConfig::from_toml("[engine]\ndispatch = \"decentralized\"").unwrap();
        assert_eq!(cfg.dispatch, Some(DispatchMode::Decentralized));
    }

    #[test]
    fn engine_choice_roundtrip() {
        for e in [
            EngineChoice::Graphi,
            EngineChoice::Sequential,
            EngineChoice::Naive,
            EngineChoice::TensorFlowLike,
        ] {
            assert_eq!(EngineChoice::parse(e.name()), Some(e));
        }
    }
}
