//! Regeneration of every table and figure in the paper's evaluation.
//!
//! Each `figN`/`tableN` function reproduces one artifact (workload,
//! parameter sweep, baseline, and the same rows/series the paper reports)
//! and returns the rendered text; rows are also recorded into the supplied
//! [`BenchRunner`] so `cargo bench` and `graphi bench` emit CSV for
//! plotting. Expected *shapes* (who wins, where crossovers fall) are
//! documented per function and asserted loosely in `rust/tests/`.

use crate::engine::{
    Engine, GraphiEngine, NaiveEngine, SequentialEngine, SimEnv, TensorFlowLikeEngine,
};
use crate::graph::op::{EwKind, OpKind};
use crate::graph::GraphStats;
use crate::models::{self, ModelKind, ModelSize};
use crate::sim::topology::PlacementKind;
use crate::util::bench::BenchRunner;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// The paper's microbenchmark operations (§3.2).
pub fn ref_gemm() -> OpKind {
    OpKind::MatMul { m: 64, k: 512, n: 512 }
}

pub fn ref_elementwise() -> OpKind {
    OpKind::Elementwise { n: 32_768, arity: 2, kind: EwKind::Arith }
}

const THREAD_SWEEP: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// **Fig 2** — scalability of a single GEMM / element-wise op vs thread
/// count. Expected shape: GEMM saturates ≈8 threads, element-wise ≈16;
/// both waste most of the chip when given all 64 cores.
pub fn fig2(runner: &mut BenchRunner) -> String {
    let env = SimEnv::knl_deterministic();
    let mut t = Table::new(&["threads", "GEMM GFLOPS", "elementwise GFLOPS"]);
    for &k in &THREAD_SWEEP {
        let g = env.cost.flops_rate(&ref_gemm(), k) / 1e9;
        let e = env.cost.flops_rate(&ref_elementwise(), k) / 1e9;
        runner.record_with_metric(
            &format!("gemm-{k}t"),
            &[("op", "gemm".into()), ("threads", k.to_string())],
            env.cost.duration_us(&ref_gemm(), k),
            Some((g, "GFLOPS")),
        );
        runner.record_with_metric(
            &format!("ew-{k}t"),
            &[("op", "elementwise".into()), ("threads", k.to_string())],
            env.cost.duration_us(&ref_elementwise(), k),
            Some((e, "GFLOPS")),
        );
        t.row(&[k.to_string(), format!("{g:.1}"), format!("{e:.3}")]);
    }
    format!("Fig 2 — single-op scalability (saturation: GEMM ≈8, ew ≈16)\n{}", t.render())
}

/// **Fig 3** — aggregate FLOPS of multiple concurrent op instances, pinned
/// vs OS-managed threads. Expected shape: pinned wins, by up to ~45 % at
/// high occupancy.
pub fn fig3(runner: &mut BenchRunner) -> String {
    let env = SimEnv::knl_deterministic();
    let interference = env.interference();
    let mut rng = Rng::new(7);
    let threads_per = 8usize;
    let mut t = Table::new(&["executors", "GEMM pinned", "GEMM OS", "ew pinned", "ew OS", "gap"]);
    for executors in [1usize, 2, 4, 8] {
        let total = executors * threads_per;
        let mut agg = |op: &OpKind, pinned: bool| -> f64 {
            let base = env.cost.duration_us(op, threads_per);
            let mean_factor = if pinned {
                1.0
            } else {
                // average over placements — the sim's stochastic factor
                let n = 200;
                (0..n)
                    .map(|_| interference.unpinned_factor(total, env.cost.machine.cores, &mut rng))
                    .sum::<f64>()
                    / n as f64
            };
            executors as f64 * op.flops() / (base * mean_factor * 1e-6)
        };
        let gp = agg(&ref_gemm(), true) / 1e9;
        let go = agg(&ref_gemm(), false) / 1e9;
        let ep = agg(&ref_elementwise(), true) / 1e9;
        let eo = agg(&ref_elementwise(), false) / 1e9;
        runner.record_with_metric(
            &format!("gemm-pinned-{executors}x{threads_per}"),
            &[("op", "gemm".into()), ("executors", executors.to_string()), ("pinned", "1".into())],
            0.0,
            Some((gp, "GFLOPS")),
        );
        runner.record_with_metric(
            &format!("gemm-os-{executors}x{threads_per}"),
            &[("op", "gemm".into()), ("executors", executors.to_string()), ("pinned", "0".into())],
            0.0,
            Some((go, "GFLOPS")),
        );
        t.row(&[
            format!("{executors}x{threads_per}"),
            format!("{gp:.1}"),
            format!("{go:.1}"),
            format!("{ep:.3}"),
            format!("{eo:.3}"),
            format!("{:.0}%", 100.0 * (gp / go - 1.0)),
        ]);
    }
    format!("Fig 3 — pinned vs OS-managed placement (paper: pinned up to +45%)\n{}", t.render())
}

/// Best-profiled Graphi fleet for a model (cheap static inference + small
/// search, mirroring §7.3's "possible to infer good settings through
/// static analysis").
fn graphi_best(graph: &crate::graph::Graph, env: &SimEnv) -> (usize, usize, f64) {
    let stats = GraphStats::compute(graph);
    let mut candidates = vec![(2, 32), (4, 16), (8, 8), (16, 4), (32, 2)];
    if stats.max_width >= 6 {
        candidates.push((6, 10));
    }
    candidates.push((3, 21));
    let mut best = (1usize, 64usize, f64::INFINITY);
    for (e, t) in candidates {
        let m = GraphiEngine::new(e, t).run(graph, env).makespan_us;
        if m < best.2 {
            best = (e, t, m);
        }
    }
    best
}

/// **Fig 5** — batch training time, TensorFlow-like vs Graphi, 4 models ×
/// 3 sizes. Expected shape: Graphi wins everywhere, 2.1–9.5×; PathNet
/// largest (LIBXSMM + 6-wide parallelism), GoogleNet smallest headroom.
pub fn fig5(runner: &mut BenchRunner, sizes: &[ModelSize]) -> String {
    let mut t = Table::new(&["model", "size", "graphi fleet", "graphi", "tensorflow", "speedup"]);
    for kind in [ModelKind::Lstm, ModelKind::PhasedLstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        for &size in sizes {
            let graph = models::build(kind, size);
            let env = SimEnv::knl(0xF16_5 ^ kind as u64 ^ (size as u64) << 4);
            let (e, th, graphi_us) = graphi_best(&graph, &env);
            // "results of the best parallelization settings for both"
            // (§7.2): TensorFlow gets its best inter/intra split too.
            let tf_us = [(2usize, 32usize), (4, 16), (8, 8), (1, 64)]
                .iter()
                .map(|&(i, t)| TensorFlowLikeEngine::new(i, t).run(&graph, &env).makespan_us)
                .fold(f64::INFINITY, f64::min);
            let speedup = tf_us / graphi_us;
            runner.record_with_metric(
                &format!("{}-{}", kind.name(), size.name()),
                &[
                    ("model", kind.name().into()),
                    ("size", size.name().into()),
                    ("graphi_us", format!("{graphi_us:.1}")),
                    ("tf_us", format!("{tf_us:.1}")),
                ],
                graphi_us,
                Some((speedup, "x-vs-TF")),
            );
            t.row(&[
                kind.name().into(),
                size.name().into(),
                format!("{e}x{th}"),
                crate::util::fmt_us(graphi_us),
                crate::util::fmt_us(tf_us),
                format!("{speedup:.2}x"),
            ]);
        }
    }
    format!("Fig 5 — Graphi vs TensorFlow-like (paper: 2.1–9.5×)\n{}", t.render())
}

/// **Fig 6** — relative batch time vs executor configuration, against the
/// sequential engine. Expected shape: parallel wins (up to ~3×); optimum
/// tracks graph width (8–16 for LSTM, 6 for PathNet, 2–3 for GoogleNet);
/// performance decays past the optimum, worst for large models.
pub fn fig6(runner: &mut BenchRunner, sizes: &[ModelSize]) -> String {
    let mut out = String::from("Fig 6 — Graphi parallelism sweep (relative to sequential S64)\n");
    for kind in [ModelKind::Lstm, ModelKind::PhasedLstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        for &size in sizes {
            let graph = models::build(kind, size);
            let env = SimEnv::knl(0xF16_6 ^ kind as u64 ^ (size as u64) << 4);
            let seq = SequentialEngine::new(64).run(&graph, &env).makespan_us;
            let mut configs: Vec<(usize, usize)> = vec![(2, 32), (4, 16), (8, 8), (16, 4), (32, 2)];
            if kind == ModelKind::PathNet {
                configs.push((6, 10)); // §7.3: 6 modules per layer
            }
            if kind == ModelKind::GoogleNet {
                configs.push((3, 21)); // §7.3: 2-3 parallel branches
            }
            let mut t = Table::new(&["config", "batch time", "relative to S64"]);
            t.row(&["S64".into(), crate::util::fmt_us(seq), "1.00".into()]);
            for (e, th) in configs {
                let us = GraphiEngine::new(e, th).run(&graph, &env).makespan_us;
                runner.record_with_metric(
                    &format!("{}-{}-{e}x{th}", kind.name(), size.name()),
                    &[
                        ("model", kind.name().into()),
                        ("size", size.name().into()),
                        ("executors", e.to_string()),
                        ("threads", th.to_string()),
                    ],
                    us,
                    Some((us / seq, "rel-to-S64")),
                );
                t.row(&[format!("{e}x{th}"), crate::util::fmt_us(us), format!("{:.2}", us / seq)]);
            }
            out.push_str(&format!("\n{} / {}\n{}", kind.name(), size.name(), t.render()));
        }
    }
    out
}

/// **Table 2** — Graphi scheduler vs naive shared-queue scheduler,
/// interference-free (both pinned, same primitives). Expected: Graphi
/// 0.81–0.96 relative time, with bigger wins on LSTM-family (more small
/// ops → more queue contention) and smaller on GoogleNet.
pub fn table2(runner: &mut BenchRunner, size: ModelSize) -> String {
    let configs = [(2usize, 32usize), (4, 16), (8, 8), (16, 4), (32, 2)];
    let kinds = [ModelKind::Lstm, ModelKind::PhasedLstm, ModelKind::PathNet, ModelKind::GoogleNet];
    let mut t = Table::new(&["parallelism", "LSTM", "PhasedLSTM", "PathNet", "GoogleNet"]);
    let mut out_rows = Vec::new();
    for (e, th) in configs {
        let mut row = vec![format!("{e}x{th}")];
        for kind in kinds {
            let graph = models::build(kind, size);
            let env = SimEnv::knl(0x7AB_2 ^ kind as u64 ^ ((e as u64) << 8));
            let graphi = GraphiEngine::new(e, th).run(&graph, &env).makespan_us;
            let naive = NaiveEngine::new(e, th).run(&graph, &env).makespan_us;
            let rel = graphi / naive;
            runner.record_with_metric(
                &format!("{}-{e}x{th}", kind.name()),
                &[
                    ("model", kind.name().into()),
                    ("executors", e.to_string()),
                    ("threads", th.to_string()),
                ],
                graphi,
                Some((rel, "rel-to-naive")),
            );
            row.push(format!("{rel:.2}"));
        }
        out_rows.push(row);
    }
    for row in &out_rows {
        t.row(row);
    }
    format!(
        "Table 2 — Graphi vs naive scheduler, {} models (paper: 0.81–0.96)\n{}",
        size.name(),
        t.render()
    )
}

/// **§6 ablations** — design choices the paper discusses:
/// scheduling policy, placement, stream stores, profiled levels, and the
/// team-resize cost that kills dynamic executor counts.
pub fn ablations(runner: &mut BenchRunner) -> String {
    let kind = ModelKind::Lstm;
    let size = ModelSize::Medium;
    let graph = models::build(kind, size);
    let env = SimEnv::knl(0xAB1A);
    let base = GraphiEngine::new(8, 8);
    let base_us = base.run(&graph, &env).makespan_us;
    let mut t = Table::new(&["variant", "batch time", "vs default"]);
    t.row(&["graphi 8x8 (default)".into(), crate::util::fmt_us(base_us), "1.00".into()]);

    let mut variant = |name: &str, engine: GraphiEngine, runner: &mut BenchRunner| -> String {
        let us = engine.run(&graph, &env).makespan_us;
        runner.record_with_metric(
            name,
            &[("variant", name.to_string())],
            us,
            Some((us / base_us, "rel-to-default")),
        );
        format!("{:.3}", us / base_us)
    };

    use crate::engine::Policy;
    for policy in [Policy::Fifo, Policy::Lifo, Policy::Random, Policy::AntiCritical] {
        let rel = variant(
            &format!("policy-{}", policy.name()),
            base.clone().with_policy(policy),
            runner,
        );
        t.row(&[format!("policy: {}", policy.name()), "-".into(), rel]);
    }
    // Even 8-thread teams are tile-aligned whether or not we ask for it
    // (§5.2 chooses even teams for exactly that reason), so the shared-L2
    // ablation needs an odd team size where packing actually straddles
    // tiles: 7 executors × 9 threads.
    let shared_us = GraphiEngine {
        placement: PlacementKind::PinnedSharedTiles,
        ..GraphiEngine::new(7, 9)
    }
    .run(&graph, &env)
    .makespan_us;
    let aligned_us = GraphiEngine::new(7, 9).run(&graph, &env).makespan_us;
    runner.record_with_metric(
        "placement-shared-tiles-7x9",
        &[("variant", "placement-shared-tiles-7x9".into())],
        shared_us,
        Some((shared_us / aligned_us, "rel-to-aligned")),
    );
    t.row(&[
        "placement: tile-straddling 7x9 (vs aligned 7x9)".into(),
        "-".into(),
        format!("{:.3}", shared_us / aligned_us),
    ]);
    let rel = variant(
        "placement-os",
        GraphiEngine { placement: PlacementKind::OsManaged, ..base.clone() },
        runner,
    );
    t.row(&["placement: OS-managed".into(), "-".into(), rel]);
    let rel = variant(
        "no-stream-stores",
        GraphiEngine { stream_stores: false, ..base.clone() },
        runner,
    );
    t.row(&["no stream stores".into(), "-".into(), rel]);
    let rel = variant(
        "unit-levels",
        GraphiEngine { profiled_levels: false, ..base.clone() },
        runner,
    );
    t.row(&["structure-only levels (no profiler)".into(), "-".into(), rel]);
    // §6 cache-affinity: preferred-executor dispatch with warm-L2 credit
    let rel = variant(
        "locality-preferred-executor",
        GraphiEngine { locality: true, ..base.clone() },
        runner,
    );
    t.row(&["cache-affinity (preferred executor)".into(), "-".into(), rel]);

    // dynamic executor count (§6): a real two-phase engine that drains the
    // forward pass, pays the OpenMP team reconfiguration, and runs the
    // backward pass on a doubled fleet
    let dynamic_us = crate::engine::DynamicFleetEngine::new((8, 8), (16, 4))
        .run(&graph, &env)
        .makespan_us;
    runner.record_with_metric(
        "dynamic-executors",
        &[("variant", "dynamic-executors".into())],
        dynamic_us,
        Some((dynamic_us / base_us, "rel-to-default")),
    );
    t.row(&[
        "dynamic 8x8 → 16x4 fleet (real resize)".into(),
        crate::util::fmt_us(dynamic_us),
        format!("{:.3}", dynamic_us / base_us),
    ]);

    // §6's other rejected idea: heterogeneous executor classes — CPU time
    // drops, makespan does not improve
    {
        let hetero = crate::engine::HeterogeneousEngine::paper_default();
        let hr = hetero.run(&graph, &env);
        let rel = hr.makespan_us / base_us;
        let cpu_hetero =
            crate::engine::heterogeneous::cpu_time_us(&hr, &hetero.team_map()) / 1e6;
        let base_run = base.run(&graph, &env);
        let cpu_sym = crate::engine::heterogeneous::cpu_time_us(&base_run, &vec![8; 8]) / 1e6;
        runner.record_with_metric(
            "heterogeneous-classes",
            &[("variant", "heterogeneous-classes".into())],
            hr.makespan_us,
            Some((rel, "rel-to-default")),
        );
        t.row(&[
            format!("heterogeneous 2x16+4x4+16x1 (cpu {cpu_hetero:.1}s vs {cpu_sym:.1}s)"),
            crate::util::fmt_us(hr.makespan_us),
            format!("{rel:.3}"),
        ]);
    }

    // fault injection: one straggler executor at 3× slowdown — CP-first
    // rebalances around it, the naive queue cannot do better
    let straggle = GraphiEngine { straggler: Some((0, 3.0)), ..base.clone() }
        .run(&graph, &env)
        .makespan_us;
    runner.record_with_metric(
        "straggler-3x",
        &[("variant", "straggler-3x".into())],
        straggle,
        Some((straggle / base_us, "rel-to-default")),
    );
    t.row(&[
        "straggler executor (3× slower)".into(),
        crate::util::fmt_us(straggle),
        format!("{:.3}", straggle / base_us),
    ]);

    format!(
        "§6 ablations on {}/{} (team resize {} — why dynamic fleets lose)\n{}",
        kind.name(),
        size.name(),
        crate::util::fmt_us(env.interference().team_resize_us()),
        t.render()
    )
}

/// **§9 generalization** — Graphi on a Skylake-SP Xeon Platinum 8180
/// (28 cores, private L2). The paper: "we also have verified that Graphi
/// achieves favorable speedup on the latest multicore CPUs (Intel Xeon
/// Platinum 8180)". Expected shape: parallel still wins, with a smaller
/// optimal fleet (fewer cores to split).
pub fn skylake(runner: &mut BenchRunner) -> String {
    use crate::cost::{Calibration, CostModel, Machine};
    let env = SimEnv {
        cost: CostModel { machine: Machine::skylake8180(), cal: Calibration::default() },
        seed: 0x5C_1,
    };
    let worker_cores = 26; // 28 − scheduler − light-weight executor
    let mut t = Table::new(&["model", "S26", "best fleet", "best", "speedup"]);
    for kind in [ModelKind::Lstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        let graph = models::build(kind, ModelSize::Medium);
        let seq = SequentialEngine::new(worker_cores).run(&graph, &env).makespan_us;
        let mut best = (0usize, 0usize, f64::INFINITY);
        for (e, th) in [(2usize, 13usize), (3, 8), (4, 6), (6, 4), (13, 2)] {
            let us = GraphiEngine::new(e, th).run(&graph, &env).makespan_us;
            if us < best.2 {
                best = (e, th, us);
            }
        }
        let speedup = seq / best.2;
        runner.record_with_metric(
            &format!("{}-medium", kind.name()),
            &[("model", kind.name().into()), ("machine", "skylake8180".into())],
            best.2,
            Some((speedup, "x-vs-seq")),
        );
        t.row(&[
            kind.name().into(),
            crate::util::fmt_us(seq),
            format!("{}x{}", best.0, best.1),
            crate::util::fmt_us(best.2),
            format!("{speedup:.2}x"),
        ]);
    }
    format!(
        "§9 generalization — Graphi on Xeon Platinum 8180 (28-core Skylake-SP)\n{}",
        t.render()
    )
}

/// **§9 NUMA future work** — KNL's SNC-4 sub-NUMA clustering mode vs the
/// paper's quadrant mode. Domain-contained executors gain a little local
/// latency; executors straddling the 17-core domains pay a cross-domain
/// penalty on memory-bound ops. With Graphi's contiguous packing the two
/// effects nearly cancel — the quantitative version of §9's "further
/// optimizing Graphi for challenging memory hierarchies such as NUMA"
/// being left as future work.
pub fn numa(runner: &mut BenchRunner) -> String {
    use crate::cost::{Calibration, CostModel, Machine};
    let graph = models::build(ModelKind::Lstm, ModelSize::Medium);
    let mut t = Table::new(&["mode", "fleet", "batch time", "vs quadrant"]);
    let mut quadrant_base = 0.0;
    for (mode, machine) in [("quadrant", Machine::knl7250()), ("snc4", Machine::knl7250_snc4())] {
        let env = SimEnv {
            cost: CostModel { machine, cal: Calibration::default() },
            seed: 0x40A,
        };
        for (e, th) in [(4usize, 16usize), (8, 8)] {
            let us = GraphiEngine::new(e, th).run(&graph, &env).makespan_us;
            if mode == "quadrant" && (e, th) == (4, 16) {
                quadrant_base = us;
            }
            runner.record_with_metric(
                &format!("{mode}-{e}x{th}"),
                &[("mode", mode.into()), ("executors", e.to_string())],
                us,
                Some((us / quadrant_base.max(1e-9), "rel-to-quadrant-4x16")),
            );
            t.row(&[
                mode.into(),
                format!("{e}x{th}"),
                crate::util::fmt_us(us),
                format!("{:.3}", us / quadrant_base),
            ]);
        }
    }
    format!(
        "§9 NUMA — quadrant vs SNC-4 under Graphi's contiguous packing
{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::{BenchConfig, BenchRunner};

    fn runner() -> BenchRunner {
        BenchRunner::with_config("test", BenchConfig::default())
    }

    #[test]
    fn fig2_produces_sweep() {
        let mut r = runner();
        let text = fig2(&mut r);
        assert!(text.contains("64"));
        assert_eq!(r.results.len(), 14);
    }

    #[test]
    fn fig3_pinned_wins() {
        let mut r = runner();
        let text = fig3(&mut r);
        assert!(text.contains("gap"));
        // last row gap should be positive
        let last = text.lines().last().unwrap();
        assert!(!last.contains("-"), "pinned must win: {last}");
    }

    #[test]
    fn table2_small_runs() {
        let mut r = runner();
        let text = table2(&mut r, ModelSize::Small);
        assert!(text.contains("LSTM"));
        assert_eq!(r.results.len(), 20);
    }
}
