//! Report writers: collect [`ExperimentResult`]s and render the paper's
//! table/figure formats (text, markdown, CSV) plus a JSON dump.

use crate::util::json::Json;
use crate::util::table::Table;

use super::driver::ExperimentResult;

/// A collection of results rendered together.
#[derive(Default)]
pub struct Report {
    results: Vec<ExperimentResult>,
}

impl Report {
    pub fn new() -> Report {
        Report::default()
    }

    pub fn push(&mut self, r: ExperimentResult) {
        self.results.push(r);
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    pub fn results(&self) -> &[ExperimentResult] {
        &self.results
    }

    /// Text table of all results; if a baseline title is given, adds a
    /// relative-time column against it (the paper's normalized plots).
    pub fn render(&self, baseline: Option<&str>) -> String {
        let base = baseline.and_then(|b| {
            self.results
                .iter()
                .find(|r| r.engine_name.contains(b) || r.config.title == b)
                .map(|r| r.mean_makespan_us)
        });
        let mut header = vec!["experiment", "model", "fleet", "batch time", "std"];
        if base.is_some() {
            header.push("relative");
        }
        let mut t = Table::new(&header);
        for r in &self.results {
            let mut row = vec![
                r.config.title.clone(),
                format!("{}/{}", r.config.model.name(), r.config.size.name()),
                format!("{}x{}", r.fleet.0, r.fleet.1),
                crate::util::fmt_us(r.mean_makespan_us),
                crate::util::fmt_us(r.std_us),
            ];
            if let Some(b) = base {
                row.push(format!("{:.2}", r.mean_makespan_us / b));
            }
            t.row(&row);
        }
        t.render()
    }

    /// CSV rows for downstream plotting.
    pub fn csv(&self) -> String {
        let mut out = String::from(
            "title,model,size,engine,executors,threads,mean_makespan_us,std_us,iterations,utilization\n",
        );
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.3},{:.3},{},{:.4}\n",
                r.config.title,
                r.config.model.name(),
                r.config.size.name(),
                r.engine_name,
                r.fleet.0,
                r.fleet.1,
                r.mean_makespan_us,
                r.std_us,
                r.iterations,
                r.last.metrics.utilization(r.last.makespan_us),
            ));
        }
        out
    }

    /// JSON array.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(|r| r.to_json()).collect())
    }

    /// Write CSV + JSON next to each other under `dir/<stem>.{csv,json}`.
    pub fn write_files(&self, dir: &str, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(format!("{dir}/{stem}.csv"), self.csv())?;
        std::fs::write(format!("{dir}/{stem}.json"), self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::ExperimentConfig;
    use crate::coordinator::driver::Driver;
    use crate::models::{ModelKind, ModelSize};

    fn result(title: &str) -> ExperimentResult {
        let cfg = ExperimentConfig {
            title: title.into(),
            model: ModelKind::Mlp,
            size: ModelSize::Small,
            executors: Some(2),
            threads_per: Some(8),
            iterations: 1,
            ..Default::default()
        };
        Driver::run(&cfg)
    }

    #[test]
    fn render_with_baseline() {
        let mut rep = Report::new();
        rep.push(result("base"));
        rep.push(result("other"));
        let text = rep.render(Some("base"));
        assert!(text.contains("relative"));
        assert!(text.contains("1.00"));
    }

    #[test]
    fn csv_has_rows() {
        let mut rep = Report::new();
        rep.push(result("x"));
        let csv = rep.csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("mlp"));
    }

    #[test]
    fn files_written() {
        let dir = std::env::temp_dir().join(format!("graphi-report-{}", std::process::id()));
        let mut rep = Report::new();
        rep.push(result("w"));
        rep.write_files(dir.to_str().unwrap(), "test").unwrap();
        assert!(dir.join("test.csv").is_file());
        assert!(dir.join("test.json").is_file());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
