//! Process-wide metrics registry.
//!
//! Engines and the runtime increment named counters/gauges; reports and
//! long-running drivers snapshot them. Thread-safe, lock-free on the hot
//! path (atomic counters), suitable for use inside executor threads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge storing an f64 (bit-cast through u64).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Registry of named metrics.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, &'static Counter>>,
    gauges: Mutex<BTreeMap<String, &'static Gauge>>,
}

impl Registry {
    /// The process-global registry.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::default)
    }

    /// Get or create a counter. The returned reference is `'static`
    /// (metrics live for the process lifetime), so hot paths can cache it.
    pub fn counter(&self, name: &str) -> &'static Counter {
        let mut map = self.counters.lock().unwrap();
        if let Some(c) = map.get(name) {
            return c;
        }
        let leaked: &'static Counter = Box::leak(Box::default());
        map.insert(name.to_string(), leaked);
        leaked
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str) -> &'static Gauge {
        let mut map = self.gauges.lock().unwrap();
        if let Some(g) = map.get(name) {
            return g;
        }
        let leaked: &'static Gauge = Box::leak(Box::default());
        map.insert(name.to_string(), leaked);
        leaked
    }

    /// Snapshot all metrics.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.insert(k.clone(), c.get() as f64);
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.insert(k.clone(), g.get());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let r = Registry::default();
        let c = r.counter("ops");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name → same counter
        assert_eq!(r.counter("ops").get(), 5);
    }

    #[test]
    fn gauge_stores_floats() {
        let r = Registry::default();
        r.gauge("util").set(0.75);
        assert_eq!(r.gauge("util").get(), 0.75);
    }

    #[test]
    fn snapshot_merges() {
        let r = Registry::default();
        r.counter("a").inc();
        r.gauge("b").set(2.5);
        let snap = r.snapshot();
        assert_eq!(snap["a"], 1.0);
        assert_eq!(snap["b"], 2.5);
    }

    #[test]
    fn concurrent_increments() {
        let r = Registry::default();
        let c = r.counter("par");
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
