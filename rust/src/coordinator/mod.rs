//! Experiment coordination: configs, drivers, metrics and reports.
//!
//! This is the "launcher" layer a downstream user touches: describe an
//! experiment in a TOML config (or CLI flags), run it through
//! [`driver::Driver`], get structured results (text table / CSV / JSON)
//! plus optional Chrome traces.
//!
//! * [`config`]  — typed experiment configuration + TOML loading
//! * [`driver`]  — builds the model, instantiates engines, runs iterations
//! * [`metrics`] — a process-wide metrics registry (counters/gauges)
//! * [`report`]  — rendering results to the paper's table/figure formats

pub mod config;
pub mod driver;
pub mod figures;
pub mod metrics;
pub mod report;

pub use config::{EngineChoice, ExperimentConfig};
pub use driver::{Driver, ExperimentResult};
