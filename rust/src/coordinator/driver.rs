//! The experiment driver: config → model → (profile) → engine → results.

use crate::engine::{
    export_chrome_trace, DispatchMode, Engine, GraphiEngine, NaiveEngine, Profiler, RunResult,
    SequentialEngine, SessionTraceExport, SimEnv, TensorFlowLikeEngine,
};
use crate::graph::{Graph, GraphStats};
use crate::models;
use crate::util::stats::Welford;

use super::config::{EngineChoice, ExperimentConfig};

/// Aggregated outcome of one experiment.
#[derive(Debug)]
pub struct ExperimentResult {
    pub config: ExperimentConfig,
    pub engine_name: String,
    /// Chosen (executors, threads) — profiled or explicit.
    pub fleet: (usize, usize),
    pub mean_makespan_us: f64,
    pub std_us: f64,
    pub iterations: usize,
    pub graph_stats: GraphStats,
    /// §5.1 memory plan over the topological order: peak arena footprint
    /// with buffer sharing — the number serve-mode admission budgets
    /// against the 16 GB MCDRAM.
    pub memory_arena_bytes: u64,
    /// The no-sharing baseline (Σ of all output buffer sizes).
    pub memory_total_bytes: u64,
    /// `memory_total_bytes / memory_arena_bytes`.
    pub memory_sharing_ratio: f64,
    /// Last iteration's full result (trace source).
    pub last: RunResult,
}

/// Runs experiments.
pub struct Driver;

impl Driver {
    /// Execute the experiment described by `cfg`.
    pub fn run(cfg: &ExperimentConfig) -> ExperimentResult {
        let graph = models::build(cfg.model, cfg.size);
        Self::run_on(cfg, &graph)
    }

    /// Execute on an already-built graph (lets callers reuse graphs).
    pub fn run_on(cfg: &ExperimentConfig, graph: &Graph) -> ExperimentResult {
        let env = SimEnv::knl(cfg.seed);
        let graph_stats = GraphStats::compute(graph);
        let fleet = Self::resolve_fleet(cfg, graph, &env, &graph_stats);
        let engine = Self::build_engine(cfg, fleet, graph, &graph_stats);

        let mut acc = Welford::new();
        let mut last = None;
        for iter in 0..cfg.iterations.max(1) {
            let env_i = SimEnv { cost: env.cost.clone(), seed: cfg.seed ^ ((iter as u64) << 32) };
            let result = engine.run(graph, &env_i);
            acc.push(result.makespan_us);
            last = Some(result);
        }
        let last = last.expect("at least one iteration");
        if let Some(path) = &cfg.trace_path {
            // same session-aware writer the serve exporter uses, so a
            // single-run trace diffs cleanly against a serve-mode one
            let durations: Vec<f64> =
                graph.nodes().iter().map(|n| env.cost.duration_us(&n.kind, fleet.1)).collect();
            let levels = crate::graph::levels(graph, &durations);
            let session = SessionTraceExport {
                label: format!(
                    "{}-{} ({})",
                    cfg.model.name(),
                    cfg.size.name(),
                    engine.name()
                ),
                graph,
                levels: Some(&levels),
                records: &last.records,
                start_us: 0.0,
                end_us: last.makespan_us,
                outcome: "done".to_string(),
            };
            let text = export_chrome_trace(std::slice::from_ref(&session), &[], fleet.0);
            if let Some(parent) = std::path::Path::new(path).parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            if let Err(e) = std::fs::write(path, text) {
                crate::log_warn!("failed to write trace {path}: {e}");
            }
        }
        let memory = crate::graph::plan_memory(graph, &graph.topo_order());
        ExperimentResult {
            config: cfg.clone(),
            engine_name: engine.name(),
            fleet,
            mean_makespan_us: acc.mean(),
            std_us: acc.std(),
            iterations: cfg.iterations.max(1),
            graph_stats,
            memory_arena_bytes: memory.arena_bytes,
            memory_total_bytes: memory.total_bytes,
            memory_sharing_ratio: memory.sharing_ratio(),
            last,
        }
    }

    /// Pick the fleet shape: explicit config wins; otherwise run the
    /// profiler's symmetric-config search (§4.2) with the model-specific
    /// extra configurations §7.3 mentions.
    fn resolve_fleet(
        cfg: &ExperimentConfig,
        graph: &Graph,
        env: &SimEnv,
        stats: &GraphStats,
    ) -> (usize, usize) {
        if let (Some(e), Some(t)) = (cfg.executors, cfg.threads_per) {
            return (e, t);
        }
        if cfg.engine == EngineChoice::Sequential {
            return (1, 64);
        }
        // §7.3: PathNet gets 6×10 (6 modules), GoogleNet 3×21 (2-3 branches)
        let profiler = Profiler {
            iterations: cfg.profile_iterations.max(1),
            worker_cores: 64,
            extra_configs: crate::sim::topology::model_extras(stats.max_width),
        };
        let report = profiler.profile(graph, env);
        report.best
    }

    fn build_engine(
        cfg: &ExperimentConfig,
        fleet: (usize, usize),
        graph: &Graph,
        stats: &GraphStats,
    ) -> Box<dyn Engine> {
        let (executors, threads) = fleet;
        match cfg.engine {
            EngineChoice::Graphi => {
                let mut engine = GraphiEngine {
                    policy: cfg.policy,
                    placement: cfg.placement,
                    dispatch: cfg.dispatch.unwrap_or(DispatchMode::Centralized),
                    ..GraphiEngine::new(executors, threads)
                };
                if let Some(durations) = &cfg.profiled_durations {
                    if durations.len() == stats.nodes {
                        engine.duration_overrides = Some(durations.clone().into());
                    } else {
                        crate::log_warn!(
                            "tuning duration table covers {} ops but the graph has {}; ignoring",
                            durations.len(),
                            stats.nodes
                        );
                    }
                }
                if let Some(plan) = &cfg.phase_plan {
                    if plan.matches(graph) {
                        engine.phase_plan = Some(plan.clone());
                    } else {
                        crate::log_warn!(
                            "phase plan ({} modes at threshold {}) does not line up with \
                             this graph's phase structure; running uniformly",
                            plan.modes.len(),
                            plan.threshold
                        );
                    }
                }
                if let Some(plan) = &cfg.width_plan {
                    engine.width_plan = Some(plan.clone());
                }
                Box::new(engine)
            }
            EngineChoice::Sequential => Box::new(SequentialEngine::new(threads.max(executors))),
            EngineChoice::Naive => Box::new(NaiveEngine {
                executors,
                threads_per: threads,
                placement: cfg.placement,
            }),
            EngineChoice::TensorFlowLike => {
                Box::new(TensorFlowLikeEngine::tuned_for(stats.max_width, 68))
            }
        }
    }
}

impl ExperimentResult {
    /// One-screen human-readable summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.config.title));
        out.push_str(&format!(
            "model: {}/{}  engine: {}  fleet: {}x{}\n",
            self.config.model.name(),
            self.config.size.name(),
            self.engine_name,
            self.fleet.0,
            self.fleet.1
        ));
        out.push_str(&self.graph_stats.render());
        out.push_str(&format!(
            "batch time: {} ± {} over {} iterations\n",
            crate::util::fmt_us(self.mean_makespan_us),
            crate::util::fmt_us(self.std_us),
            self.iterations
        ));
        out.push_str(&format!(
            "executor utilization: {:.1}%  dispatches: {}  lw ops: {}\n",
            100.0 * self.last.metrics.utilization(self.last.makespan_us),
            self.last.metrics.dispatches,
            self.last.metrics.lightweight_ops,
        ));
        out.push_str(&format!(
            "memory plan (§5.1): {}\n",
            crate::graph::memory::render_summary(
                self.memory_arena_bytes,
                self.memory_total_bytes,
                self.memory_sharing_ratio,
            ),
        ));
        out
    }

    /// Structured JSON (for tooling).
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut doc = crate::util::json::Json::obj();
        doc.set("title", self.config.title.as_str())
            .set("model", self.config.model.name())
            .set("size", self.config.size.name())
            .set("engine", self.engine_name.as_str())
            .set("executors", self.fleet.0)
            .set("threads_per", self.fleet.1)
            .set("mean_makespan_us", self.mean_makespan_us)
            .set("std_us", self.std_us)
            .set("iterations", self.iterations)
            .set("nodes", self.graph_stats.nodes)
            .set("edges", self.graph_stats.edges)
            .set("utilization", self.last.metrics.utilization(self.last.makespan_us))
            .set("memory_arena_bytes", self.memory_arena_bytes)
            .set("memory_total_bytes", self.memory_total_bytes)
            .set("memory_sharing_ratio", self.memory_sharing_ratio);
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{ModelKind, ModelSize};

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig {
            model: ModelKind::Mlp,
            size: ModelSize::Small,
            executors: Some(4),
            threads_per: Some(8),
            iterations: 2,
            ..Default::default()
        }
    }

    #[test]
    fn explicit_fleet_skips_profiler() {
        let r = Driver::run(&quick_cfg());
        assert_eq!(r.fleet, (4, 8));
        assert!(r.mean_makespan_us > 0.0);
        assert_eq!(r.iterations, 2);
    }

    #[test]
    fn auto_fleet_profiles() {
        let cfg = ExperimentConfig {
            executors: None,
            threads_per: None,
            profile_iterations: 1,
            ..quick_cfg()
        };
        let r = Driver::run(&cfg);
        assert!(r.fleet.0 >= 1 && r.fleet.1 >= 1);
    }

    #[test]
    fn profiled_durations_flow_into_the_engine() {
        let nodes = crate::models::build(ModelKind::Mlp, ModelSize::Small).len();
        let cfg = ExperimentConfig {
            profiled_durations: Some(vec![2.0; nodes]),
            iterations: 1,
            ..quick_cfg()
        };
        let r = Driver::run(&cfg);
        assert!(r.mean_makespan_us > 0.0);
        // a mismatching table is ignored, not fatal
        let cfg = ExperimentConfig {
            profiled_durations: Some(vec![2.0; 3]),
            iterations: 1,
            ..quick_cfg()
        };
        let r = Driver::run(&cfg);
        assert!(r.mean_makespan_us > 0.0);
    }

    #[test]
    fn render_and_json() {
        let r = Driver::run(&quick_cfg());
        let text = r.render();
        assert!(text.contains("mlp"));
        assert!(text.contains("memory plan"), "§5.1 plan must be reported: {text}");
        assert!(text.contains("sharing"));
        let json = r.to_json().to_string_compact();
        assert!(json.contains("\"engine\""));
        assert!(json.contains("\"memory_arena_bytes\""));
        assert!(r.memory_arena_bytes > 0);
        assert!(r.memory_total_bytes >= r.memory_arena_bytes);
        assert!(r.memory_sharing_ratio >= 1.0);
    }

    #[test]
    fn trace_written() {
        let path = std::env::temp_dir().join(format!("graphi-trace-{}.json", std::process::id()));
        let cfg = ExperimentConfig {
            trace_path: Some(path.display().to_string()),
            ..quick_cfg()
        };
        let _ = Driver::run(&cfg);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        // must pass the exporter's own well-formedness validator: named
        // process, named lanes, finite non-overlapping spans
        let stats = crate::engine::validate_chrome_trace(&text).unwrap();
        assert_eq!(stats.processes, 1);
        assert!(stats.spans > 0);
        assert!(stats.instant_names.contains("done"), "{:?}", stats.instant_names);
    }

    #[test]
    fn decentralized_dispatch_flows_into_the_engine() {
        let cfg = ExperimentConfig {
            dispatch: Some(DispatchMode::Decentralized),
            iterations: 1,
            ..quick_cfg()
        };
        let r = Driver::run(&cfg);
        assert!(r.engine_name.ends_with("-decentral"), "{}", r.engine_name);
        assert!(r.mean_makespan_us > 0.0);
    }

    #[test]
    fn phase_plan_flows_into_the_engine() {
        use crate::engine::PhasePlan;
        let g = crate::models::build(ModelKind::Mlp, ModelSize::Small);
        let phases = crate::graph::width_phases(&g, 1);
        let cfg = ExperimentConfig {
            phase_plan: Some(PhasePlan::uniform(1, DispatchMode::Decentralized, phases.len())),
            iterations: 1,
            ..quick_cfg()
        };
        let r = Driver::run(&cfg);
        assert!(r.engine_name.ends_with("-phased"), "{}", r.engine_name);
        assert!(r.mean_makespan_us > 0.0);
        // a plan that does not line up is dropped with a warning, not fatal
        let cfg = ExperimentConfig {
            phase_plan: Some(PhasePlan {
                threshold: 1,
                modes: vec![DispatchMode::Centralized; 99],
            }),
            iterations: 1,
            ..quick_cfg()
        };
        let r = Driver::run(&cfg);
        assert!(!r.engine_name.ends_with("-phased"));
        assert!(r.mean_makespan_us > 0.0);
    }

    #[test]
    fn width_plan_flows_into_the_engine() {
        use crate::engine::WidthPlan;
        use crate::graph::op::OpClass;
        let mut plan = WidthPlan::uniform(1);
        plan.set(OpClass::Gemm, 2);
        let cfg = ExperimentConfig { width_plan: Some(plan), iterations: 1, ..quick_cfg() };
        let r = Driver::run(&cfg);
        assert!(r.engine_name.ends_with("-moldable"), "{}", r.engine_name);
        assert!(r.mean_makespan_us > 0.0);
        // the identity plan is a no-op, not a moldable run
        let cfg = ExperimentConfig {
            width_plan: Some(WidthPlan::uniform(1)),
            iterations: 1,
            ..quick_cfg()
        };
        let r = Driver::run(&cfg);
        assert!(!r.engine_name.contains("moldable"), "{}", r.engine_name);
    }

    #[test]
    fn all_engine_choices_run() {
        for engine in [
            EngineChoice::Graphi,
            EngineChoice::Sequential,
            EngineChoice::Naive,
            EngineChoice::TensorFlowLike,
        ] {
            let cfg = ExperimentConfig { engine, iterations: 1, ..quick_cfg() };
            let r = Driver::run(&cfg);
            assert!(r.mean_makespan_us > 0.0, "{engine:?}");
        }
    }
}
