//! LSTM / PhasedLSTM language-model training graphs.
//!
//! Follows the Zaremba et al. TensorFlow benchmark the paper bases its
//! LSTM on ([65] in the paper): a 4-layer stacked LSTM LM with per-timestep
//! embedding lookup and softmax head. Table 1a sets (sequence, neurons) to
//! (20,128)/(30,512)/(40,1024); batch is 64.
//!
//! PhasedLSTM ([42]) adds a per-cell *time gate* — a handful of extra
//! element-wise ops modulating the cell/hidden updates. The paper uses it
//! to show Graphi's network-agnosticism: the same engine speeds up both.
//!
//! Cell structure (per layer ℓ, timestep t) follows the standard fused
//! formulation (TF `BasicLSTMCell` / Zaremba): one GEMM over the
//! concatenated `[x, h]` input, then several element-wise ops — the paper's
//! "2-3 parallel operators in each cell". The single fused GEMM makes cell
//! `(t, ℓ)` depend on `(t−1, ℓ)` and `(t, ℓ−1)`: the diagonal wavefront of
//! width ≈ L that §7.3 counts ("total parallelizable operations ≈ 8-12")
//! and that cuDNN's hand-tuned LSTM exploits (§7.4).
//!
//! ```text
//! pre = [x, h[t-1]]·W + b              (one GEMM + element-wise add)
//! i, f, o, g = σ/tanh slices of pre    (four parallel activations)
//! c[t] = f⊙c[t-1] + i⊙g                (element-wise)
//! h[t] = o⊙tanh(c[t])                  (element-wise)
//! ```
//!
//! The softmax head follows the benchmark implementation too: hidden
//! states are concatenated over time and projected by a single large
//! `[B·T, H]×[H, V]` GEMM.

use crate::graph::op::{EwKind, OpKind};
use crate::graph::{Graph, NodeId};
use crate::models::common::Tape;
use crate::models::config::{batch_size, lstm_params, ModelKind, ModelSize};

/// LSTM LM hyper-parameters.
#[derive(Debug, Clone)]
pub struct LstmConfig {
    pub layers: usize,
    pub seq: usize,
    pub hidden: usize,
    pub batch: usize,
    pub vocab: usize,
    pub phased: bool,
    /// Training (fwd+bwd+SGD) or inference (fwd only, §2).
    pub training: bool,
}

impl LstmConfig {
    /// Table 1a sizes; `phased` selects PhasedLSTM.
    pub fn for_size(size: ModelSize, phased: bool) -> LstmConfig {
        let (seq, hidden) = lstm_params(size);
        LstmConfig {
            layers: 4, // §7.3: "the four-layer LSTM/PhasedLSTM model"
            seq,
            hidden,
            batch: batch_size(if phased { ModelKind::PhasedLstm } else { ModelKind::Lstm }),
            vocab: 10_000,
            phased,
            training: true,
        }
    }
}

/// Build the training graph (forward + backward + SGD updates).
pub fn build(cfg: &LstmConfig) -> Graph {
    let mut tape = Tape::new();
    let b = cfg.batch as u64;
    let h = cfg.hidden as u64;
    let v = cfg.vocab as u64;

    // initial states, one per layer
    let mut prev_h: Vec<Option<NodeId>> = vec![None; cfg.layers];
    let mut prev_c: Vec<Option<NodeId>> = vec![None; cfg.layers];
    let mut step_hiddens: Vec<NodeId> = Vec::with_capacity(cfg.seq);

    for t in 0..cfg.seq {
        // embedding lookup: memory-bound gather from the [V,H] table
        let embed = tape.param_op(
            format!("t{t}.embed"),
            OpKind::Concat { n: b * h },
            &[],
            v * h,
        );
        // per-timestep "time" input for the PhasedLSTM gate
        let time_input = if cfg.phased {
            Some(tape.op(format!("t{t}.time"), OpKind::Scalar, &[]))
        } else {
            None
        };

        let mut layer_input = embed;
        for l in 0..cfg.layers {
            let p = format!("t{t}.l{l}");
            // one fused GEMM over the concatenated [x, h[t-1]] input — the
            // recurrence edge that creates the diagonal wavefront
            let mut gemm_deps = vec![layer_input];
            if let Some(ph) = prev_h[l] {
                gemm_deps.push(ph);
            }
            let gemm = tape.param_op(
                format!("{p}.gemm"),
                OpKind::MatMul { m: b, k: 2 * h, n: 4 * h },
                &gemm_deps,
                2 * h * 4 * h,
            );
            // bias add
            let pre = tape.op(
                format!("{p}.preact"),
                OpKind::Elementwise { n: b * 4 * h, arity: 1, kind: EwKind::Arith },
                &[gemm],
            );
            // four parallel gate activations
            let gate_i = tape.op(
                format!("{p}.gate_i"),
                OpKind::Elementwise { n: b * h, arity: 1, kind: EwKind::Transcendental },
                &[pre],
            );
            let gate_f = tape.op(
                format!("{p}.gate_f"),
                OpKind::Elementwise { n: b * h, arity: 1, kind: EwKind::Transcendental },
                &[pre],
            );
            let gate_o = tape.op(
                format!("{p}.gate_o"),
                OpKind::Elementwise { n: b * h, arity: 1, kind: EwKind::Transcendental },
                &[pre],
            );
            let gate_g = tape.op(
                format!("{p}.gate_g"),
                OpKind::Elementwise { n: b * h, arity: 1, kind: EwKind::Transcendental },
                &[pre],
            );
            // cell update: c = f⊙c_prev + i⊙g
            let mut c_deps = vec![gate_i, gate_f, gate_g];
            if let Some(pc) = prev_c[l] {
                c_deps.push(pc);
            }
            let mut c_new = tape.op(
                format!("{p}.cell"),
                OpKind::Elementwise { n: b * h, arity: 4, kind: EwKind::Arith },
                &c_deps,
            );
            // hidden: h = o⊙tanh(c)
            let mut h_new = tape.op(
                format!("{p}.hidden"),
                OpKind::Elementwise { n: b * h, arity: 2, kind: EwKind::Transcendental },
                &[gate_o, c_new],
            );
            // PhasedLSTM time gate: k_t modulates both c and h
            if let Some(time) = time_input {
                let k_gate = tape.op(
                    format!("{p}.time_gate"),
                    OpKind::Elementwise { n: b * h, arity: 1, kind: EwKind::Transcendental },
                    &[time],
                );
                let mut cp_deps = vec![c_new, k_gate];
                if let Some(pc) = prev_c[l] {
                    cp_deps.push(pc);
                }
                c_new = tape.op(
                    format!("{p}.cell_phased"),
                    OpKind::Elementwise { n: b * h, arity: 3, kind: EwKind::Arith },
                    &cp_deps,
                );
                let mut hp_deps = vec![h_new, k_gate];
                if let Some(ph) = prev_h[l] {
                    hp_deps.push(ph);
                }
                h_new = tape.op(
                    format!("{p}.hidden_phased"),
                    OpKind::Elementwise { n: b * h, arity: 3, kind: EwKind::Arith },
                    &hp_deps,
                );
            }
            prev_c[l] = Some(c_new);
            prev_h[l] = Some(h_new);
            layer_input = h_new;
        }
        step_hiddens.push(layer_input);
    }

    // softmax head over the whole sequence, as in the TF benchmark: gather
    // the per-step outputs, one large projection GEMM, one softmax
    let gathered = tape.op(
        "head.concat",
        OpKind::Concat { n: b * cfg.seq as u64 * h },
        &step_hiddens,
    );
    let logits = tape.param_op(
        "head.proj",
        OpKind::MatMul { m: b * cfg.seq as u64, k: h, n: v },
        &[gathered],
        h * v,
    );
    let loss = tape.op(
        "head.softmax",
        OpKind::Softmax { batch: b * cfg.seq as u64, classes: v },
        &[logits],
    );
    let builder = if cfg.training { tape.backward(loss) } else { tape.builder };
    builder.build().expect("LSTM graph must be a valid DAG")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::GraphStats;
    use crate::models::config::ModelSize;

    #[test]
    fn medium_graph_scale() {
        let g = build(&LstmConfig::for_size(ModelSize::Medium, false));
        // 30 steps × 4 layers × ~9 fwd ops + backward ≈ few thousand
        assert!(
            (2000..6000).contains(&g.len()),
            "medium LSTM has {} nodes",
            g.len()
        );
        g.validate_order(&g.topo_order()).unwrap();
    }

    #[test]
    fn phased_adds_time_gate_ops() {
        let plain = build(&LstmConfig::for_size(ModelSize::Small, false));
        let phased = build(&LstmConfig::for_size(ModelSize::Small, true));
        assert!(phased.len() > plain.len() + 100, "time gates must add ops");
    }

    #[test]
    fn sizes_are_ordered_by_work() {
        let small = build(&LstmConfig::for_size(ModelSize::Small, false));
        let medium = build(&LstmConfig::for_size(ModelSize::Medium, false));
        let large = build(&LstmConfig::for_size(ModelSize::Large, false));
        assert!(small.total_flops() < medium.total_flops());
        assert!(medium.total_flops() < large.total_flops());
    }

    #[test]
    fn graph_has_lstm_parallelism() {
        // §7.3: "one cell from each layer can run in parallel, and there
        // are 2-3 parallel operators in each cell, so the total number of
        // parallelizable operations is around 8-12"
        let g = build(&LstmConfig::for_size(ModelSize::Medium, false));
        let stats = GraphStats::compute(&g);
        assert!(stats.max_width >= 8, "max width {} too narrow", stats.max_width);
    }

    #[test]
    fn has_sgd_updates_for_all_params() {
        let cfg = LstmConfig::for_size(ModelSize::Small, false);
        let g = build(&cfg);
        let sgd = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.kind, OpKind::SgdUpdate { .. }))
            .count();
        // per timestep: embed + 4 fused cell gemms; plus one head proj
        assert_eq!(sgd, cfg.seq * (1 + 4) + 1, "sgd updates {sgd}");
    }

    #[test]
    fn recurrent_chain_limits_depth() {
        // cell[t] must depend (transitively) on cell[t-1]
        let g = build(&LstmConfig::for_size(ModelSize::Small, false));
        let c0 = g.nodes().iter().find(|n| n.name == "t0.l0.cell").unwrap().id;
        let c1 = g.nodes().iter().find(|n| n.name == "t1.l0.cell").unwrap().id;
        // BFS from c0 must reach c1
        let mut seen = vec![false; g.len()];
        let mut stack = vec![c0];
        while let Some(v) = stack.pop() {
            if seen[v as usize] {
                continue;
            }
            seen[v as usize] = true;
            stack.extend_from_slice(g.succs(v));
        }
        assert!(seen[c1 as usize], "recurrence edge missing");
    }
}
