//! A small MLP — not part of the paper's evaluation; used by unit tests,
//! the quickstart example, and anywhere a cheap-but-nontrivial training
//! graph is needed.

use crate::graph::op::{EwKind, OpKind};
use crate::graph::Graph;
use crate::models::common::Tape;

/// MLP hyper-parameters.
#[derive(Debug, Clone)]
pub struct MlpConfig {
    pub batch: usize,
    pub input: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
}

impl Default for MlpConfig {
    fn default() -> Self {
        MlpConfig { batch: 64, input: 784, hidden: vec![512, 256], classes: 10 }
    }
}

/// Build the training graph.
pub fn build(cfg: &MlpConfig) -> Graph {
    let mut tape = Tape::new();
    let b = cfg.batch as u64;
    let input = tape.op("input", OpKind::Scalar, &[]);
    let mut x = input;
    let mut dim = cfg.input as u64;
    for (i, &h) in cfg.hidden.iter().enumerate() {
        let h = h as u64;
        let fc = tape.param_op(
            format!("fc{i}"),
            OpKind::MatMul { m: b, k: dim, n: h },
            &[x],
            dim * h,
        );
        x = tape.op(
            format!("relu{i}"),
            OpKind::Elementwise { n: b * h, arity: 1, kind: EwKind::Relu },
            &[fc],
        );
        dim = h;
    }
    let logits = tape.param_op(
        "head",
        OpKind::MatMul { m: b, k: dim, n: cfg.classes as u64 },
        &[x],
        dim * cfg.classes as u64,
    );
    let loss = tape.op(
        "softmax",
        OpKind::Softmax { batch: b, classes: cfg.classes as u64 },
        &[logits],
    );
    tape.backward(loss).build().expect("MLP graph must be a valid DAG")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_builds() {
        let g = build(&MlpConfig::default());
        assert!(g.len() > 10);
        g.validate_order(&g.topo_order()).unwrap();
    }

    #[test]
    fn sgd_per_layer() {
        let g = build(&MlpConfig::default());
        let sgd = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.kind, OpKind::SgdUpdate { .. }))
            .count();
        assert_eq!(sgd, 3); // fc0, fc1, head
    }
}
