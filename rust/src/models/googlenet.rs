//! GoogLeNet (Inception v1) training graphs.
//!
//! §7.1: "we refer to the implementation provided in TensorFlow … but vary
//! the image size and multiply the number of output filters in each
//! convolution by a constant factor (width)". Table 1c: image
//! 128/192/256, width 1/2/4, batch 32.
//!
//! Each inception module has four parallel branches (1×1; 1×1→3×3;
//! 1×1→5×5; pool→1×1) concatenated — the "2-3 parallel conv/pool
//! operations" the paper credits for GoogleNet's (modest) parallel
//! speedup, and why Fig 6 shows it peaking at 2-3 executors.

use crate::graph::op::{EwKind, OpKind};
use crate::graph::{Graph, NodeId};
use crate::models::common::Tape;
use crate::models::config::{batch_size, googlenet_params, ModelKind, ModelSize};

/// Inception module channel plan `(c1, c2r, c2, c3r, c3, c4)`.
type Inception = (u64, u64, u64, u64, u64, u64);

/// The canonical GoogLeNet channel table (Szegedy et al., Table 1).
const INCEPTIONS: &[(&str, Inception, bool)] = &[
    // name, channels, downsample-before
    ("3a", (64, 96, 128, 16, 32, 32), false),
    ("3b", (128, 128, 192, 32, 96, 64), false),
    ("4a", (192, 96, 208, 16, 48, 64), true),
    ("4b", (160, 112, 224, 24, 64, 64), false),
    ("4c", (128, 128, 256, 24, 64, 64), false),
    ("4d", (112, 144, 288, 32, 64, 64), false),
    ("4e", (256, 160, 320, 32, 128, 128), false),
    ("5a", (256, 160, 320, 32, 128, 128), true),
    ("5b", (384, 192, 384, 48, 128, 128), false),
];

/// GoogLeNet hyper-parameters.
#[derive(Debug, Clone)]
pub struct GoogleNetConfig {
    pub image: usize,
    pub width: usize,
    pub batch: usize,
    pub classes: usize,
    /// Training (fwd+bwd+SGD) or inference (fwd only, §2).
    pub training: bool,
}

impl GoogleNetConfig {
    pub fn for_size(size: ModelSize) -> GoogleNetConfig {
        let (image, width) = googlenet_params(size);
        GoogleNetConfig {
            image,
            width,
            batch: batch_size(ModelKind::GoogleNet),
            classes: 1000,
            training: true,
        }
    }
}

struct Ctx<'a> {
    tape: &'a mut Tape,
    batch: u64,
    width: u64,
}

impl<'a> Ctx<'a> {
    /// conv + ReLU; returns the ReLU node and output channels.
    fn conv_relu(
        &mut self,
        name: &str,
        input: NodeId,
        hw: u64,
        cin: u64,
        cout: u64,
        kernel: u64,
        stride: u64,
    ) -> (NodeId, u64) {
        let conv = self.tape.param_op(
            format!("{name}.conv"),
            OpKind::Conv2d { batch: self.batch, h: hw, w: hw, cin, cout, kernel, stride },
            &[input],
            cin * cout * kernel * kernel,
        );
        let ohw = hw.div_ceil(stride);
        let relu = self.tape.op(
            format!("{name}.relu"),
            OpKind::Elementwise { n: self.batch * ohw * ohw * cout, arity: 1, kind: EwKind::Relu },
            &[conv],
        );
        (relu, cout)
    }

    /// One inception module; returns (output node, output channels).
    fn inception(
        &mut self,
        name: &str,
        input: NodeId,
        hw: u64,
        cin: u64,
        plan: Inception,
    ) -> (NodeId, u64) {
        let w = self.width;
        let (c1, c2r, c2, c3r, c3, c4) = (
            plan.0 * w,
            plan.1 * w,
            plan.2 * w,
            plan.3 * w,
            plan.4 * w,
            plan.5 * w,
        );
        // four parallel branches
        let (b1, _) = self.conv_relu(&format!("{name}.b1_1x1"), input, hw, cin, c1, 1, 1);
        let (b2a, _) = self.conv_relu(&format!("{name}.b2_1x1"), input, hw, cin, c2r, 1, 1);
        let (b2, _) = self.conv_relu(&format!("{name}.b2_3x3"), b2a, hw, c2r, c2, 3, 1);
        let (b3a, _) = self.conv_relu(&format!("{name}.b3_1x1"), input, hw, cin, c3r, 1, 1);
        let (b3, _) = self.conv_relu(&format!("{name}.b3_5x5"), b3a, hw, c3r, c3, 5, 1);
        let pool = self.tape.op(
            format!("{name}.b4_pool"),
            OpKind::Pool2d { batch: self.batch, h: hw, w: hw, c: cin, window: 3, stride: 1 },
            &[input],
        );
        let (b4, _) = self.conv_relu(&format!("{name}.b4_1x1"), pool, hw, cin, c4, 1, 1);
        let cout = c1 + c2 + c3 + c4;
        let concat = self.tape.op(
            format!("{name}.concat"),
            OpKind::Concat { n: self.batch * hw * hw * cout },
            &[b1, b2, b3, b4],
        );
        (concat, cout)
    }
}

/// Build the training graph.
pub fn build(cfg: &GoogleNetConfig) -> Graph {
    let mut tape = Tape::new();
    let b = cfg.batch as u64;
    let w = cfg.width as u64;
    let input = tape.op("input", OpKind::Scalar, &[]);

    let mut ctx = Ctx { tape: &mut tape, batch: b, width: w };
    let mut hw = cfg.image as u64;

    // stem: 7×7/2 conv → pool/2 → 3×3 conv → pool/2
    let (stem1, c) = ctx.conv_relu("stem.conv7", input, hw, 3, 64 * w, 7, 2);
    hw = hw.div_ceil(2);
    let pool1 = ctx.tape.op(
        "stem.pool1",
        OpKind::Pool2d { batch: b, h: hw, w: hw, c, window: 3, stride: 2 },
        &[stem1],
    );
    hw = hw.div_ceil(2);
    let (stem2, c) = ctx.conv_relu("stem.conv3", pool1, hw, c, 192 * w, 3, 1);
    let pool2 = ctx.tape.op(
        "stem.pool2",
        OpKind::Pool2d { batch: b, h: hw, w: hw, c, window: 3, stride: 2 },
        &[stem2],
    );
    hw = hw.div_ceil(2);

    let mut node = pool2;
    let mut cin = c;
    for &(name, plan, downsample) in INCEPTIONS {
        if downsample {
            node = ctx.tape.op(
                format!("{name}.downsample"),
                OpKind::Pool2d { batch: b, h: hw, w: hw, c: cin, window: 3, stride: 2 },
                &[node],
            );
            hw = hw.div_ceil(2);
        }
        let (out, cout) = ctx.inception(name, node, hw, cin, plan);
        node = out;
        cin = cout;
    }

    // global average pool → FC → softmax
    let gap = tape.op(
        "head.avgpool",
        OpKind::Pool2d { batch: b, h: hw, w: hw, c: cin, window: hw, stride: hw },
        &[node],
    );
    let fc = tape.param_op(
        "head.fc",
        OpKind::MatMul { m: b, k: cin, n: cfg.classes as u64 },
        &[gap],
        cin * cfg.classes as u64,
    );
    let loss = tape.op(
        "head.softmax",
        OpKind::Softmax { batch: b, classes: cfg.classes as u64 },
        &[fc],
    );
    let builder = if cfg.training { tape.backward(loss) } else { tape.builder };
    builder.build().expect("GoogLeNet graph must be a valid DAG")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpClass;
    use crate::graph::stats::max_parallel_of_class;

    #[test]
    fn inception_exposes_3_to_4_parallel_convs() {
        let g = build(&GoogleNetConfig::for_size(ModelSize::Small));
        let p = max_parallel_of_class(&g, OpClass::Conv);
        assert!((3..=8).contains(&p), "parallel convs {p}");
    }

    #[test]
    fn graph_scale() {
        let g = build(&GoogleNetConfig::for_size(ModelSize::Small));
        // 9 inceptions × ~14 ops + stem + head, ×~2.5 for backward
        assert!((300..1200).contains(&g.len()), "{} nodes", g.len());
        g.validate_order(&g.topo_order()).unwrap();
    }

    #[test]
    fn width_multiplies_flops_quadratically() {
        let w1 = build(&GoogleNetConfig { image: 128, width: 1, batch: 32, classes: 1000, training: true });
        let w2 = build(&GoogleNetConfig { image: 128, width: 2, batch: 32, classes: 1000, training: true });
        let ratio = w2.total_flops() / w1.total_flops();
        assert!((3.0..5.0).contains(&ratio), "width-2 flop ratio {ratio} (expect ≈4)");
    }

    #[test]
    fn googlenet_has_bigger_ops_than_lstm() {
        // §7.4: GoogleNet ops are larger → less queue contention
        use crate::models::lstm::{build as lstm_build, LstmConfig};
        let g = build(&GoogleNetConfig::for_size(ModelSize::Medium));
        let l = lstm_build(&LstmConfig::for_size(ModelSize::Medium, false));
        let g_mean = g.total_flops() / g.len() as f64;
        let l_mean = l.total_flops() / l.len() as f64;
        assert!(g_mean > 3.0 * l_mean, "mean op size googlenet {g_mean:.2e} vs lstm {l_mean:.2e}");
    }
}
