//! PathNet training graphs.
//!
//! PathNet ([20], DeepMind) trains "paths" through a grid of modules —
//! §7.1 of the paper: 3 layers, 6 active modules per layer, each module a
//! 3×3 convolution → ReLU → 2×2 pooling; module outputs are summed between
//! layers. Table 1b sets (image, channels) to (32,16)/(48,32)/(64,48).
//! The 6 parallel modules per layer are exactly why the paper's Fig 6
//! shows PathNet peaking at 6 executors.

use crate::graph::op::{EwKind, OpKind};
use crate::graph::Graph;
use crate::models::common::Tape;
use crate::models::config::{batch_size, pathnet_params, ModelKind, ModelSize};

/// PathNet hyper-parameters.
#[derive(Debug, Clone)]
pub struct PathNetConfig {
    pub layers: usize,
    pub modules_per_layer: usize,
    pub image: usize,
    pub channels: usize,
    pub batch: usize,
    pub classes: usize,
    /// Training (fwd+bwd+SGD) or inference (fwd only, §2).
    pub training: bool,
}

impl PathNetConfig {
    pub fn for_size(size: ModelSize) -> PathNetConfig {
        let (image, channels) = pathnet_params(size);
        PathNetConfig {
            layers: 3,            // §7.1: "number of layers set to 3"
            modules_per_layer: 6, // "active modules per layer set to 6"
            image,
            channels,
            batch: batch_size(ModelKind::PathNet),
            classes: 10,
            training: true,
        }
    }
}

/// Build the training graph.
pub fn build(cfg: &PathNetConfig) -> Graph {
    let mut tape = Tape::new();
    let b = cfg.batch as u64;
    let n = cfg.channels as u64;

    let input = tape.op("input", OpKind::Scalar, &[]);
    let mut layer_in = input;
    let mut cin = 3u64; // RGB input
    let mut hw = cfg.image as u64;

    for l in 0..cfg.layers {
        let mut module_outs = Vec::with_capacity(cfg.modules_per_layer);
        for m in 0..cfg.modules_per_layer {
            let p = format!("l{l}.m{m}");
            // 3×3 conv → ReLU → 2×2 pool (§7.1)
            let conv = tape.param_op(
                format!("{p}.conv"),
                OpKind::Conv2d { batch: b, h: hw, w: hw, cin, cout: n, kernel: 3, stride: 1 },
                &[layer_in],
                cin * n * 9,
            );
            let relu = tape.op(
                format!("{p}.relu"),
                OpKind::Elementwise { n: b * hw * hw * n, arity: 1, kind: EwKind::Relu },
                &[conv],
            );
            let pool = tape.op(
                format!("{p}.pool"),
                OpKind::Pool2d { batch: b, h: hw, w: hw, c: n, window: 2, stride: 2 },
                &[relu],
            );
            module_outs.push(pool);
        }
        hw /= 2;
        // sum of module outputs feeds the next layer (PathNet's aggregation)
        let sum = tape.op(
            format!("l{l}.sum"),
            OpKind::Elementwise {
                n: b * hw * hw * n,
                arity: cfg.modules_per_layer as u64,
                kind: EwKind::Arith,
            },
            &module_outs,
        );
        layer_in = sum;
        cin = n;
    }

    // classifier head: flatten → FC → softmax
    let feat = b * hw * hw * n;
    let fc = tape.param_op(
        "head.fc",
        OpKind::MatMul { m: b, k: feat / b, n: cfg.classes as u64 },
        &[layer_in],
        (feat / b) * cfg.classes as u64,
    );
    let loss = tape.op(
        "head.softmax",
        OpKind::Softmax { batch: b, classes: cfg.classes as u64 },
        &[fc],
    );
    let builder = if cfg.training { tape.backward(loss) } else { tape.builder };
    builder.build().expect("PathNet graph must be a valid DAG")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpClass;
    use crate::graph::stats::{max_parallel_of_class, GraphStats};

    #[test]
    fn six_parallel_conv_modules() {
        let g = build(&PathNetConfig::for_size(ModelSize::Medium));
        // forward convs of one layer are mutually independent
        assert!(
            max_parallel_of_class(&g, OpClass::Conv) >= 6,
            "PathNet must expose ≥6 parallel convolutions"
        );
    }

    #[test]
    fn graph_scale_reasonable() {
        let g = build(&PathNetConfig::for_size(ModelSize::Small));
        assert!((100..600).contains(&g.len()), "{} nodes", g.len());
        g.validate_order(&g.topo_order()).unwrap();
    }

    #[test]
    fn conv_count_matches_structure() {
        let cfg = PathNetConfig::for_size(ModelSize::Small);
        let g = build(&cfg);
        let fwd_convs = 3 * 6; // layers × modules
        let convs = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Conv2d { .. }))
            .count();
        // fwd + dgrad + wgrad per conv = 3 (first layer's dgrad skipped for
        // the input-less source is not the case here: input node exists)
        assert_eq!(convs, fwd_convs * 3, "conv census {convs}");
    }

    #[test]
    fn sizes_scale_flops() {
        let s = build(&PathNetConfig::for_size(ModelSize::Small)).total_flops();
        let l = build(&PathNetConfig::for_size(ModelSize::Large)).total_flops();
        assert!(l > 5.0 * s, "large/small flop ratio {}", l / s);
    }

    #[test]
    fn depth_grows_with_layers() {
        let g = build(&PathNetConfig::for_size(ModelSize::Small));
        let stats = GraphStats::compute(&g);
        // 3 layers × 3 ops + head, doubled for backward
        assert!(stats.depth >= 12, "depth {}", stats.depth);
    }
}
