//! Shared model-compiler machinery: the autodiff tape.
//!
//! Training graphs are forward + backward + update ops. Rather than each
//! model hand-writing its backward pass (error-prone at GoogLeNet scale),
//! compilers record forward ops on a [`Tape`]; [`Tape::backward`] then
//! appends, for every recorded op `X` that influences the loss:
//!
//! * an **input-grad** op `dX` computing the gradient w.r.t. `X`'s inputs —
//!   depends on `X` (forward activations) and on the input-grads of all of
//!   `X`'s consumers (the incoming output-gradient);
//! * if `X` carries parameters, a **weight-grad** op running *in parallel*
//!   with `dX` (they share inputs but not outputs — exactly how dA/dW
//!   decompose for GEMM/conv), feeding an **SGD update** op.
//!
//! The resulting DAG has the doubled-parallelism backward structure the
//! paper notes in §6 ("typically the number of parallel operations doubles
//! during the backward pass").

use crate::graph::op::{EwKind, OpKind};
use crate::graph::{GraphBuilder, NodeId};

/// One recorded forward op.
#[derive(Debug, Clone)]
struct Record {
    id: NodeId,
    kind: OpKind,
    preds: Vec<NodeId>,
    /// Parameter tensor elements, if this op consumes trainable weights.
    param_elems: Option<u64>,
}

/// Records forward ops and generates the backward pass.
#[derive(Debug, Default)]
pub struct Tape {
    pub builder: GraphBuilder,
    records: Vec<Record>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    /// Add a forward op depending on `deps`.
    pub fn op(&mut self, name: impl Into<String>, kind: OpKind, deps: &[NodeId]) -> NodeId {
        let id = self.builder.add_after(name, kind.clone(), deps);
        self.records.push(Record { id, kind, preds: deps.to_vec(), param_elems: None });
        id
    }

    /// Add a forward op that consumes a parameter tensor of `param_elems`
    /// elements (weight grad + SGD update will be generated).
    pub fn param_op(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        deps: &[NodeId],
        param_elems: u64,
    ) -> NodeId {
        let id = self.builder.add_after(name, kind.clone(), deps);
        self.records.push(Record { id, kind, preds: deps.to_vec(), param_elems: Some(param_elems) });
        id
    }

    /// Add an op that is *not* differentiated (data loading, metrics).
    pub fn untracked(&mut self, name: impl Into<String>, kind: OpKind, deps: &[NodeId]) -> NodeId {
        self.builder.add_after(name, kind, deps)
    }

    /// Number of recorded forward ops.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Generate the backward pass seeded at `loss`, returning the builder
    /// for any final additions. Also appends one SGD update per param op.
    pub fn backward(mut self, loss: NodeId) -> GraphBuilder {
        let n = self.records.len();
        // index of record by node id
        let mut rec_of: std::collections::HashMap<NodeId, usize> = std::collections::HashMap::new();
        for (i, r) in self.records.iter().enumerate() {
            rec_of.insert(r.id, i);
        }
        // consumers within the tape
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, r) in self.records.iter().enumerate() {
            for &p in &r.preds {
                if let Some(&pi) = rec_of.get(&p) {
                    consumers[pi].push(i);
                }
            }
        }
        // which records influence the loss (reverse reachability)
        let loss_rec = *rec_of.get(&loss).expect("loss must be a recorded op");
        let mut influences = vec![false; n];
        influences[loss_rec] = true;
        // records are appended in topological order by construction, so a
        // single reverse sweep settles reachability
        for i in (0..n).rev() {
            if consumers[i].iter().any(|&c| influences[c]) {
                influences[i] = true;
            }
        }

        // seed: dLoss
        let seed = self.builder.add_after("loss.grad_seed", OpKind::Scalar, &[loss]);

        // generate grads in reverse topological (reverse insertion) order
        let mut dgrad: Vec<Option<NodeId>> = vec![None; n];
        for i in (0..n).rev() {
            if !influences[i] {
                continue;
            }
            let record = self.records[i].clone();
            // incoming output-gradient: consumers' input-grad nodes
            let mut incoming: Vec<NodeId> = consumers[i]
                .iter()
                .filter_map(|&c| dgrad[c])
                .collect();
            if i == loss_rec {
                incoming.push(seed);
            }
            if incoming.is_empty() {
                continue; // no gradient flows here
            }
            let name = &self.builder_name(record.id);
            // input-grad op — skip for pure sources (their grads feed nothing)
            let needs_dgrad = !record.preds.is_empty();
            if needs_dgrad {
                let kind = dgrad_kind(&record.kind);
                let mut deps = vec![record.id];
                deps.extend_from_slice(&incoming);
                let g = self.builder.add_after(format!("{name}.dgrad"), kind, &deps);
                dgrad[i] = Some(g);
            }
            // weight-grad + update, in parallel with the input-grad
            if let Some(elems) = record.param_elems {
                let kind = wgrad_kind(&record.kind);
                let mut deps = vec![record.id];
                deps.extend_from_slice(&incoming);
                let wg = self.builder.add_after(format!("{name}.wgrad"), kind, &deps);
                self.builder
                    .add_after(format!("{name}.sgd"), OpKind::SgdUpdate { n: elems }, &[wg]);
            }
        }
        self.builder
    }

    /// Reconstruct a node's name for grad naming. GraphBuilder does not
    /// expose names, so we track via records' order — names are only for
    /// humans, so a positional fallback is fine.
    fn builder_name(&self, id: NodeId) -> String {
        format!("n{id}")
    }
}

/// Gradient-w.r.t.-inputs op for a forward op.
fn dgrad_kind(kind: &OpKind) -> OpKind {
    match *kind {
        // dA = dC · Bᵀ : [m,n]×[n,k]
        OpKind::MatMul { m, k, n } => OpKind::MatMul { m, k: n, n: k },
        // transposed conv, same cost shape
        OpKind::Conv2d { batch, h, w, cin, cout, kernel, stride } => {
            OpKind::Conv2d { batch, h, w, cin: cout, cout: cin, kernel, stride }
        }
        OpKind::Pool2d { batch, h, w, c, .. } => {
            OpKind::Elementwise { n: batch * h * w * c, arity: 2, kind: EwKind::Relu }
        }
        OpKind::Elementwise { n, arity, kind } => OpKind::Elementwise {
            n,
            arity: arity + 1,
            kind: match kind {
                EwKind::Transcendental => EwKind::Transcendental,
                EwKind::FusedGates => EwKind::FusedGates,
                _ => EwKind::Arith,
            },
        },
        OpKind::Reduce { n } => OpKind::Elementwise { n, arity: 1, kind: EwKind::Arith },
        OpKind::Softmax { batch, classes } => {
            OpKind::Elementwise { n: batch * classes, arity: 2, kind: EwKind::Arith }
        }
        OpKind::Concat { n } => OpKind::Concat { n },
        OpKind::SgdUpdate { .. } => unreachable!("SGD updates are not differentiated"),
        OpKind::Scalar => OpKind::Scalar,
    }
}

/// Gradient-w.r.t.-weights op for a parameterized forward op.
fn wgrad_kind(kind: &OpKind) -> OpKind {
    match *kind {
        // dB = Aᵀ · dC : [k,m]×[m,n]
        OpKind::MatMul { m, k, n } => OpKind::MatMul { m: k, k: m, n },
        OpKind::Conv2d { batch, h, w, cin, cout, kernel, stride } => {
            OpKind::Conv2d { batch, h, w, cin, cout, kernel, stride }
        }
        // bias-style params on elementwise ops: reduction over the batch
        OpKind::Elementwise { n, .. } => OpKind::Reduce { n },
        ref other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::GraphStats;

    /// y = relu(x·W); loss = softmax(y·V)
    fn two_layer_tape() -> (Tape, NodeId) {
        let mut t = Tape::new();
        let x = t.op("x", OpKind::Scalar, &[]);
        let h = t.param_op("fc1", OpKind::MatMul { m: 8, k: 16, n: 32 }, &[x], 16 * 32);
        let r = t.op("relu", OpKind::Elementwise { n: 8 * 32, arity: 1, kind: EwKind::Relu }, &[h]);
        let o = t.param_op("fc2", OpKind::MatMul { m: 8, k: 32, n: 10 }, &[r], 32 * 10);
        let loss = t.op("loss", OpKind::Softmax { batch: 8, classes: 10 }, &[o]);
        (t, loss)
    }

    #[test]
    fn backward_generates_valid_dag() {
        let (t, loss) = two_layer_tape();
        let fwd_ops = t.len();
        let g = t.backward(loss).build().unwrap();
        assert!(g.len() > fwd_ops, "backward must add ops");
        g.validate_order(&g.topo_order()).unwrap();
    }

    #[test]
    fn param_ops_get_wgrad_and_sgd() {
        let (t, loss) = two_layer_tape();
        let g = t.backward(loss).build().unwrap();
        let sgd_count = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.kind, OpKind::SgdUpdate { .. }))
            .count();
        assert_eq!(sgd_count, 2, "one SGD update per parameterized op");
    }

    #[test]
    fn wgrad_gemm_shapes_transpose() {
        let fwd = OpKind::MatMul { m: 8, k: 32, n: 10 };
        assert_eq!(wgrad_kind(&fwd), OpKind::MatMul { m: 32, k: 8, n: 10 });
        assert_eq!(dgrad_kind(&fwd), OpKind::MatMul { m: 8, k: 10, n: 32 });
    }

    #[test]
    fn backward_flops_about_double_forward() {
        // classic rule: backward ≈ 2× forward flops for gemm-dominated nets
        let (t, loss) = two_layer_tape();
        let fwd_flops: f64 = [
            OpKind::MatMul { m: 8, k: 16, n: 32 }.flops(),
            OpKind::MatMul { m: 8, k: 32, n: 10 }.flops(),
        ]
        .iter()
        .sum();
        let g = t.backward(loss).build().unwrap();
        let gemm_flops: f64 = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.kind, OpKind::MatMul { .. }))
            .map(|n| n.kind.flops())
            .sum();
        let ratio = gemm_flops / fwd_flops;
        assert!((2.4..=3.1).contains(&ratio), "fwd+bwd/fwd gemm ratio {ratio} (expect ~3)");
    }

    #[test]
    fn backward_widens_the_graph() {
        // §6: parallelism roughly doubles in the backward pass (dgrad and
        // wgrad run in parallel).
        let (t, loss) = two_layer_tape();
        let g = t.backward(loss).build().unwrap();
        let stats = GraphStats::compute(&g);
        assert!(stats.max_width >= 2, "dgrad/wgrad should be parallel");
    }

    #[test]
    fn untracked_ops_get_no_grad() {
        let mut t = Tape::new();
        let x = t.op("x", OpKind::Scalar, &[]);
        let y = t.param_op("fc", OpKind::MatMul { m: 2, k: 2, n: 2 }, &[x], 4);
        t.untracked("metrics", OpKind::Scalar, &[y]);
        let loss = y;
        let g = t.backward(loss).build().unwrap();
        // metrics node exists but nothing depends on it
        let metrics = g.nodes().iter().find(|n| n.name == "metrics").unwrap();
        assert_eq!(g.out_degree(metrics.id), 0);
    }

    #[test]
    fn dead_branches_are_not_differentiated() {
        let mut t = Tape::new();
        let x = t.op("x", OpKind::Scalar, &[]);
        let live = t.param_op("live", OpKind::MatMul { m: 2, k: 2, n: 2 }, &[x], 4);
        // recorded but does not reach the loss
        t.param_op("dead", OpKind::MatMul { m: 2, k: 2, n: 2 }, &[x], 4);
        let g = t.backward(live).build().unwrap();
        let sgd_count = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.kind, OpKind::SgdUpdate { .. }))
            .count();
        assert_eq!(sgd_count, 1, "dead branch must not produce updates");
    }
}
