//! The evaluation workload grid (Table 1 of the paper).

/// Which network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Lstm,
    PhasedLstm,
    PathNet,
    GoogleNet,
    /// Not in the paper; small net for tests/examples.
    Mlp,
}

impl ModelKind {
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Lstm => "lstm",
            ModelKind::PhasedLstm => "phasedlstm",
            ModelKind::PathNet => "pathnet",
            ModelKind::GoogleNet => "googlenet",
            ModelKind::Mlp => "mlp",
        }
    }

    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "lstm" => Some(ModelKind::Lstm),
            "phasedlstm" | "phased_lstm" | "phased-lstm" => Some(ModelKind::PhasedLstm),
            "pathnet" => Some(ModelKind::PathNet),
            "googlenet" => Some(ModelKind::GoogleNet),
            "mlp" => Some(ModelKind::Mlp),
            _ => None,
        }
    }
}

/// Small / Medium / Large per Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelSize {
    Small,
    Medium,
    Large,
}

impl ModelSize {
    pub fn name(self) -> &'static str {
        match self {
            ModelSize::Small => "small",
            ModelSize::Medium => "medium",
            ModelSize::Large => "large",
        }
    }

    pub fn parse(s: &str) -> Option<ModelSize> {
        match s.to_ascii_lowercase().as_str() {
            "small" | "s" => Some(ModelSize::Small),
            "medium" | "m" => Some(ModelSize::Medium),
            "large" | "l" => Some(ModelSize::Large),
            _ => None,
        }
    }

    pub fn all() -> [ModelSize; 3] {
        [ModelSize::Small, ModelSize::Medium, ModelSize::Large]
    }
}

/// Table 1a: LSTM/PhasedLSTM — (sequence length, neurons).
pub fn lstm_params(size: ModelSize) -> (usize, usize) {
    match size {
        ModelSize::Small => (20, 128),
        ModelSize::Medium => (30, 512),
        ModelSize::Large => (40, 1024),
    }
}

/// Table 1b: PathNet — (image size, neurons i.e. conv channels).
pub fn pathnet_params(size: ModelSize) -> (usize, usize) {
    match size {
        ModelSize::Small => (32, 16),
        ModelSize::Medium => (48, 32),
        ModelSize::Large => (64, 48),
    }
}

/// Table 1c: GoogleNet — (image size, width multiplier).
pub fn googlenet_params(size: ModelSize) -> (usize, usize) {
    match size {
        ModelSize::Small => (128, 1),
        ModelSize::Medium => (192, 2),
        ModelSize::Large => (256, 4),
    }
}

/// Batch sizes (§7.1: 64 for LSTM/PhasedLSTM/PathNet, 32 for GoogleNet to
/// fit MCDRAM).
pub fn batch_size(kind: ModelKind) -> usize {
    match kind {
        ModelKind::GoogleNet => 32,
        _ => 64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        assert_eq!(lstm_params(ModelSize::Medium), (30, 512));
        assert_eq!(pathnet_params(ModelSize::Large), (64, 48));
        assert_eq!(googlenet_params(ModelSize::Small), (128, 1));
    }

    #[test]
    fn parse_roundtrip() {
        for kind in [ModelKind::Lstm, ModelKind::PhasedLstm, ModelKind::PathNet, ModelKind::GoogleNet] {
            assert_eq!(ModelKind::parse(kind.name()), Some(kind));
        }
        for size in ModelSize::all() {
            assert_eq!(ModelSize::parse(size.name()), Some(size));
        }
        assert_eq!(ModelKind::parse("nope"), None);
    }

    #[test]
    fn batch_sizes_match_paper() {
        assert_eq!(batch_size(ModelKind::Lstm), 64);
        assert_eq!(batch_size(ModelKind::GoogleNet), 32);
    }
}
