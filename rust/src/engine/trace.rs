//! Execution traces.
//!
//! §5.2: "we use the profiling results to visualize the execution process,
//! i.e. placing the operations to their running executors' timelines. This
//! has been immensely helpful in analysis and debugging." Traces also back
//! the §7.4 observation that critical-path-first scheduling recovers the
//! cuDNN-style diagonal wavefront on LSTM automatically.

use crate::graph::{Graph, NodeId};
use crate::util::json::Json;

/// Executor id used for ops run on the light-weight executor (§5.2).
pub const LIGHTWEIGHT_EXECUTOR: u32 = u32::MAX;

/// One executed operation.
#[derive(Debug, Clone, PartialEq)]
pub struct OpRecord {
    pub node: NodeId,
    pub executor: u32,
    pub start_us: f64,
    pub end_us: f64,
}

impl OpRecord {
    pub fn duration_us(&self) -> f64 {
        self.end_us - self.start_us
    }
}

/// A full execution trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub records: Vec<OpRecord>,
}

impl Trace {
    /// Export in Chrome `about:tracing` / Perfetto JSON format.
    pub fn to_chrome_json(&self, graph: &Graph) -> String {
        let mut events = Vec::with_capacity(self.records.len());
        for r in &self.records {
            let node = graph.node(r.node);
            let mut e = Json::obj();
            e.set("name", node.name.as_str())
                .set("cat", node.kind.mnemonic())
                .set("ph", "X")
                .set("ts", r.start_us)
                .set("dur", r.duration_us())
                .set("pid", 1u64)
                .set(
                    "tid",
                    if r.executor == LIGHTWEIGHT_EXECUTOR { 9999u64 } else { r.executor as u64 },
                );
            events.push(e);
        }
        let mut doc = Json::obj();
        doc.set("traceEvents", Json::Arr(events));
        doc.set("displayTimeUnit", "ms");
        doc.to_string_pretty()
    }

    /// Pearson correlation between a node's graph depth and its start
    /// time. A near-1 value on a recurrent net's forward cells indicates
    /// the diagonal-wavefront execution pattern §7.4 describes.
    pub fn depth_time_correlation(&self, graph: &Graph) -> f64 {
        let depths = crate::graph::stats::node_depths(graph);
        let xs: Vec<f64> = self.records.iter().map(|r| depths[r.node as usize] as f64).collect();
        let ys: Vec<f64> = self.records.iter().map(|r| r.start_us).collect();
        pearson(&xs, &ys)
    }

    /// Render executor timelines as ASCII art (for terminal inspection).
    pub fn render_ascii(&self, graph: &Graph, width: usize) -> String {
        if self.records.is_empty() {
            return String::from("(empty trace)\n");
        }
        let makespan = self.records.iter().map(|r| r.end_us).fold(0.0, f64::max);
        let mut executors: Vec<u32> = self.records.iter().map(|r| r.executor).collect();
        executors.sort_unstable();
        executors.dedup();
        let mut out = String::new();
        for &e in &executors {
            let mut line = vec![b'.'; width];
            for r in self.records.iter().filter(|r| r.executor == e) {
                let a = ((r.start_us / makespan) * width as f64) as usize;
                let b = (((r.end_us / makespan) * width as f64) as usize).min(width);
                let c = graph.node(r.node).kind.mnemonic().as_bytes()[0];
                for cell in line.iter_mut().take(b.max(a + 1).min(width)).skip(a.min(width - 1)) {
                    *cell = c;
                }
            }
            let label = if e == LIGHTWEIGHT_EXECUTOR { "lw".to_string() } else { format!("e{e:02}") };
            out.push_str(&format!("{label} |{}|\n", String::from_utf8_lossy(&line)));
        }
        out.push_str(&format!("makespan: {}\n", crate::util::fmt_us(makespan)));
        out
    }
}

fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

/// Validate a record set against the graph: every op exactly once,
/// dependencies respected, per-executor serialization, makespan agrees.
pub fn validate_records(graph: &Graph, records: &[OpRecord], makespan_us: f64) -> Result<(), String> {
    if records.len() != graph.len() {
        return Err(format!("{} records for {} nodes", records.len(), graph.len()));
    }
    let mut end_of = vec![f64::NAN; graph.len()];
    let mut start_of = vec![f64::NAN; graph.len()];
    for r in records {
        if (r.node as usize) >= graph.len() {
            return Err(format!("record for unknown node {}", r.node));
        }
        if !end_of[r.node as usize].is_nan() {
            return Err(format!("node {} executed twice", r.node));
        }
        if r.end_us < r.start_us {
            return Err(format!("node {} ends before it starts", r.node));
        }
        end_of[r.node as usize] = r.end_us;
        start_of[r.node as usize] = r.start_us;
    }
    const EPS: f64 = 1e-6;
    for v in 0..graph.len() as NodeId {
        for &p in graph.preds(v) {
            if end_of[p as usize] > start_of[v as usize] + EPS {
                return Err(format!(
                    "dependency violated: {} (ends {:.3}) must finish before {} (starts {:.3})",
                    graph.node(p).name,
                    end_of[p as usize],
                    graph.node(v).name,
                    start_of[v as usize],
                ));
            }
        }
    }
    // per-executor non-overlap
    let mut by_exec: std::collections::BTreeMap<u32, Vec<&OpRecord>> = Default::default();
    for r in records {
        by_exec.entry(r.executor).or_default().push(r);
    }
    for (e, mut rs) in by_exec {
        rs.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        for w in rs.windows(2) {
            if w[0].end_us > w[1].start_us + EPS {
                return Err(format!(
                    "executor {e} overlap: node {} [{:.3},{:.3}] vs node {} [{:.3},{:.3}]",
                    w[0].node, w[0].start_us, w[0].end_us, w[1].node, w[1].start_us, w[1].end_us
                ));
            }
        }
    }
    let max_end = records.iter().map(|r| r.end_us).fold(0.0, f64::max);
    if (max_end - makespan_us).abs() > 1e-3 {
        return Err(format!("makespan {makespan_us} != last end {max_end}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    fn chain() -> Graph {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let c = b.add("c", OpKind::Scalar);
        b.depend(a, c);
        b.build().unwrap()
    }

    fn good_records() -> Vec<OpRecord> {
        vec![
            OpRecord { node: 0, executor: 0, start_us: 0.0, end_us: 1.0 },
            OpRecord { node: 1, executor: 1, start_us: 1.0, end_us: 3.0 },
        ]
    }

    #[test]
    fn valid_records_pass() {
        validate_records(&chain(), &good_records(), 3.0).unwrap();
    }

    #[test]
    fn dependency_violation_caught() {
        let mut rs = good_records();
        rs[1].start_us = 0.5;
        rs[1].end_us = 3.0;
        assert!(validate_records(&chain(), &rs, 3.0).is_err());
    }

    #[test]
    fn executor_overlap_caught() {
        let g = {
            let mut b = GraphBuilder::new();
            b.add("a", OpKind::Scalar);
            b.add("b", OpKind::Scalar);
            b.build().unwrap()
        };
        let rs = vec![
            OpRecord { node: 0, executor: 0, start_us: 0.0, end_us: 2.0 },
            OpRecord { node: 1, executor: 0, start_us: 1.0, end_us: 3.0 },
        ];
        assert!(validate_records(&g, &rs, 3.0).unwrap_err().contains("overlap"));
    }

    #[test]
    fn missing_and_duplicate_records_caught() {
        assert!(validate_records(&chain(), &good_records()[..1], 1.0).is_err());
        let rs = vec![
            OpRecord { node: 0, executor: 0, start_us: 0.0, end_us: 1.0 },
            OpRecord { node: 0, executor: 1, start_us: 1.0, end_us: 2.0 },
        ];
        assert!(validate_records(&chain(), &rs, 2.0).is_err());
    }

    #[test]
    fn wrong_makespan_caught() {
        assert!(validate_records(&chain(), &good_records(), 99.0).is_err());
    }

    #[test]
    fn chrome_json_parses() {
        let g = chain();
        let t = Trace { records: good_records() };
        let text = t.to_chrome_json(&g);
        let doc = crate::util::json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "X");
    }

    #[test]
    fn correlation_of_ordered_chain_is_one() {
        let g = chain();
        let t = Trace { records: good_records() };
        let c = t.depth_time_correlation(&g);
        assert!((c - 1.0).abs() < 1e-9, "correlation {c}");
    }

    #[test]
    fn ascii_render_mentions_executors() {
        let g = chain();
        let t = Trace { records: good_records() };
        let art = t.render_ascii(&g, 40);
        assert!(art.contains("e00"));
        assert!(art.contains("e01"));
        assert!(art.contains("makespan"));
    }
}
