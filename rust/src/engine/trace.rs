//! Execution traces and the Chrome-trace/Perfetto exporter.
//!
//! §5.2: "we use the profiling results to visualize the execution process,
//! i.e. placing the operations to their running executors' timelines. This
//! has been immensely helpful in analysis and debugging." Traces also back
//! the §7.4 observation that critical-path-first scheduling recovers the
//! cuDNN-style diagonal wavefront on LSTM automatically.
//!
//! Beyond the in-terminal ASCII rendering, everything exports to the Chrome
//! trace-event JSON format (viewable in `chrome://tracing` or
//! <https://ui.perfetto.dev>) through one writer, [`ChromeTraceBuilder`]:
//!
//! - [`export_chrome_trace`] lays out a multi-session run — one `pid` per
//!   session (named via `process_name` metadata), one `tid` per executor,
//!   ops as `ph:"X"` spans whose args carry node id, op kind and CP level,
//!   and fleet/lifecycle transitions (steals, parks, mode switches,
//!   admitted/started/terminal) as `ph:"i"` instants. Both the threaded
//!   runtime (`graphi run/serve --trace-chrome`) and the simulator's
//!   per-session record splits export through this same function, which is
//!   what makes the exporter differentially testable.
//! - [`validate_chrome_trace`] re-parses an exported document and checks
//!   the well-formedness invariants (metadata present for every span's
//!   pid/tid, finite non-negative durations, per-tid span non-overlap).

use crate::engine::DispatchMode;
use crate::graph::{Graph, NodeId};
use crate::util::json::Json;

/// Executor id used for ops run on the light-weight executor (§5.2).
pub const LIGHTWEIGHT_EXECUTOR: u32 = u32::MAX;

/// Executor-lane id for fleet events not tied to a single executor
/// (scheduler-thread parks, phase-plan mode switches).
pub const FLEET_LANE: u32 = u32::MAX;

/// The `pid` of the synthetic "fleet" process in exported traces; session
/// pids are allocated above it.
pub const FLEET_PID: u64 = 1;

/// One executed operation.
#[derive(Debug, Clone, PartialEq)]
pub struct OpRecord {
    pub node: NodeId,
    pub executor: u32,
    pub start_us: f64,
    pub end_us: f64,
}

impl OpRecord {
    pub fn duration_us(&self) -> f64 {
        self.end_us - self.start_us
    }
}

/// A scheduling event observed by the fleet's per-executor event sinks
/// (`runtime/fleet.rs`), timestamped on the fleet's shared clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetEvent {
    /// Microseconds since the owning fleet's epoch. Single-session runs
    /// re-base this onto the session's own clock before reporting.
    pub t_us: f64,
    /// Executor index, or [`FLEET_LANE`] for fleet-level events.
    pub executor: u32,
    pub kind: FleetEventKind,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FleetEventKind {
    /// An executor stole work belonging to session `session` from another
    /// executor's deque (or the NUMA-remote half of the victim ranking).
    Steal { session: u64, cross_domain: bool },
    /// An idle executor (or the centralized scheduler thread) exhausted its
    /// spin→yield budget and parked on the event counter.
    Park,
    /// A phased run switched dispatch mode at this instant.
    ModeSwitch { from: DispatchMode, to: DispatchMode },
}

impl FleetEventKind {
    pub fn name(&self) -> &'static str {
        match self {
            FleetEventKind::Steal { .. } => "steal",
            FleetEventKind::Park => "park",
            FleetEventKind::ModeSwitch { .. } => "mode_switch",
        }
    }
}

/// A full execution trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub records: Vec<OpRecord>,
}

impl Trace {
    /// Export in Chrome `about:tracing` / Perfetto JSON format: a single
    /// process with one named lane per executor. Session-aware exports go
    /// through [`export_chrome_trace`] instead.
    pub fn to_chrome_json(&self, graph: &Graph) -> String {
        let mut b = ChromeTraceBuilder::new();
        b.process_name(FLEET_PID, "graphi");
        let mut execs: Vec<u32> = self
            .records
            .iter()
            .map(|r| r.executor)
            .filter(|&e| e != LIGHTWEIGHT_EXECUTOR)
            .collect();
        execs.sort_unstable();
        execs.dedup();
        for &e in &execs {
            b.thread_name(FLEET_PID, e as u64, &format!("executor {e}"));
        }
        // The lightweight executor's lane sits just above the largest real
        // executor id. (It used to be a hardcoded 9999, which collided with
        // real executor ids on large fleets.)
        let lw_tid = execs.last().map_or(0, |&m| m as u64 + 1);
        if self.records.iter().any(|r| r.executor == LIGHTWEIGHT_EXECUTOR) {
            b.thread_name(FLEET_PID, lw_tid, "lightweight");
        }
        for r in &self.records {
            let node = graph.node(r.node);
            let tid = if r.executor == LIGHTWEIGHT_EXECUTOR { lw_tid } else { r.executor as u64 };
            let mut args = Json::obj();
            args.set("node", r.node as u64).set("kind", node.kind.mnemonic());
            b.span(FLEET_PID, tid, r.start_us, r.duration_us(), &node.name, node.kind.mnemonic(), args);
        }
        b.finish()
    }

    /// Pearson correlation between a node's graph depth and its start
    /// time. A near-1 value on a recurrent net's forward cells indicates
    /// the diagonal-wavefront execution pattern §7.4 describes.
    pub fn depth_time_correlation(&self, graph: &Graph) -> f64 {
        let depths = crate::graph::stats::node_depths(graph);
        let xs: Vec<f64> = self.records.iter().map(|r| depths[r.node as usize] as f64).collect();
        let ys: Vec<f64> = self.records.iter().map(|r| r.start_us).collect();
        pearson(&xs, &ys)
    }

    /// Render executor timelines as ASCII art (for terminal inspection).
    pub fn render_ascii(&self, graph: &Graph, width: usize) -> String {
        if self.records.is_empty() {
            return String::from("(empty trace)\n");
        }
        let makespan = self.records.iter().map(|r| r.end_us).fold(0.0, f64::max);
        // A zero makespan (all zero-duration ops at t=0) would make the
        // time→column projection NaN; collapse everything to column 0.
        let scale = if makespan > 0.0 { width as f64 / makespan } else { 0.0 };
        let mut executors: Vec<u32> = self.records.iter().map(|r| r.executor).collect();
        executors.sort_unstable();
        executors.dedup();
        let mut out = String::new();
        for &e in &executors {
            let mut line = vec![b'.'; width];
            for r in self.records.iter().filter(|r| r.executor == e) {
                let a = ((r.start_us * scale) as usize).min(width.saturating_sub(1));
                let b = ((r.end_us * scale) as usize).min(width);
                let c = graph.node(r.node).kind.mnemonic().as_bytes()[0];
                for cell in line.iter_mut().take(b.max(a + 1).min(width)).skip(a) {
                    *cell = c;
                }
            }
            let label = if e == LIGHTWEIGHT_EXECUTOR { "lw".to_string() } else { format!("e{e:02}") };
            out.push_str(&format!("{label} |{}|\n", String::from_utf8_lossy(&line)));
        }
        out.push_str(&format!("makespan: {}\n", crate::util::fmt_us(makespan)));
        out
    }
}

/// Low-level Chrome trace-event writer: collects `ph:"M"/"X"/"i"` events
/// and serializes the `traceEvents` document. All timestamps are in µs
/// (the format's native unit).
#[derive(Default)]
pub struct ChromeTraceBuilder {
    events: Vec<Json>,
}

impl ChromeTraceBuilder {
    pub fn new() -> ChromeTraceBuilder {
        ChromeTraceBuilder { events: Vec::new() }
    }

    /// `process_name` metadata: names `pid`'s row in the viewer.
    pub fn process_name(&mut self, pid: u64, name: &str) {
        self.metadata("process_name", pid, 0, name);
    }

    /// `thread_name` metadata: names the `(pid, tid)` lane in the viewer.
    pub fn thread_name(&mut self, pid: u64, tid: u64, name: &str) {
        self.metadata("thread_name", pid, tid, name);
    }

    fn metadata(&mut self, kind: &str, pid: u64, tid: u64, name: &str) {
        let mut args = Json::obj();
        args.set("name", name);
        let mut e = Json::obj();
        e.set("name", kind).set("ph", "M").set("pid", pid).set("tid", tid).set("args", args);
        self.events.push(e);
    }

    /// A complete `ph:"X"` span.
    pub fn span(&mut self, pid: u64, tid: u64, ts_us: f64, dur_us: f64, name: &str, cat: &str, args: Json) {
        let mut e = Json::obj();
        e.set("name", name)
            .set("cat", cat)
            .set("ph", "X")
            .set("ts", ts_us)
            .set("dur", dur_us)
            .set("pid", pid)
            .set("tid", tid)
            .set("args", args);
        self.events.push(e);
    }

    /// A thread-scoped `ph:"i"` instant event.
    pub fn instant(&mut self, pid: u64, tid: u64, ts_us: f64, name: &str, args: Json) {
        let mut e = Json::obj();
        e.set("name", name)
            .set("ph", "i")
            .set("s", "t")
            .set("ts", ts_us)
            .set("pid", pid)
            .set("tid", tid)
            .set("args", args);
        self.events.push(e);
    }

    pub fn event_count(&self) -> usize {
        self.events.len()
    }

    pub fn finish(self) -> String {
        let mut doc = Json::obj();
        doc.set("traceEvents", Json::Arr(self.events));
        doc.set("displayTimeUnit", "ms");
        doc.to_string_pretty()
    }
}

/// One session's contribution to a multi-session Chrome trace.
pub struct SessionTraceExport<'a> {
    /// `process_name` for the session's pid, e.g. `"session 3 (mlp-small-inf)"`.
    pub label: String,
    pub graph: &'a Graph,
    /// Optional CP levels, exported into each span's args when present.
    pub levels: Option<&'a [f64]>,
    /// Op records on the session's own clock (µs since submit).
    pub records: &'a [OpRecord],
    /// Submit instant on the shared fleet timeline, in µs.
    pub start_us: f64,
    /// Terminal instant on the shared fleet timeline, in µs.
    pub end_us: f64,
    /// Terminal cause: `"done"`, `"failed"`, `"cancelled"`, `"deadline"`, `"stalled"`.
    pub outcome: String,
}

fn tid_of(executor: u32, lw_tid: u64) -> u64 {
    if executor == LIGHTWEIGHT_EXECUTOR { lw_tid } else { executor as u64 }
}

/// Export a multi-session run as one Chrome trace document.
///
/// Layout: pid [`FLEET_PID`] is the fleet itself — one lane per executor
/// carrying steal/park instants plus a `"fleet"` lane for scheduler parks
/// and mode switches. Each session gets its own pid (in input order) with
/// op spans on per-executor lanes, a `"lightweight"` lane above every real
/// executor id, and a `"lifecycle"` lane with admitted/started/terminal
/// instants. Both the threaded runtime and the simulator's record splits
/// export through here, so the two can be diffed span-for-span.
pub fn export_chrome_trace(
    sessions: &[SessionTraceExport<'_>],
    fleet_events: &[FleetEvent],
    executors: usize,
) -> String {
    let mut b = ChromeTraceBuilder::new();

    b.process_name(FLEET_PID, "fleet");
    for e in 0..executors {
        b.thread_name(FLEET_PID, e as u64, &format!("executor {e}"));
    }
    let fleet_lane_tid = executors as u64;
    b.thread_name(FLEET_PID, fleet_lane_tid, "fleet");
    for ev in fleet_events {
        let tid = if ev.executor == FLEET_LANE {
            fleet_lane_tid
        } else {
            (ev.executor as u64).min(fleet_lane_tid)
        };
        let mut args = Json::obj();
        match ev.kind {
            FleetEventKind::Steal { session, cross_domain } => {
                args.set("session", session).set("cross_domain", cross_domain);
            }
            FleetEventKind::Park => {}
            FleetEventKind::ModeSwitch { from, to } => {
                args.set("from", from.name()).set("to", to.name());
            }
        }
        b.instant(FLEET_PID, tid, ev.t_us, ev.kind.name(), args);
    }

    // One lightweight lane id shared by all sessions, above both the fleet
    // width and the largest executor id appearing in any record.
    let max_real = sessions
        .iter()
        .flat_map(|s| s.records.iter())
        .map(|r| r.executor)
        .filter(|&e| e != LIGHTWEIGHT_EXECUTOR)
        .max();
    let lw_tid = (executors as u64).max(max_real.map_or(0, |m| m as u64 + 1));

    for (i, s) in sessions.iter().enumerate() {
        let pid = FLEET_PID + 1 + i as u64;
        b.process_name(pid, &s.label);
        let mut tids: Vec<u64> = s.records.iter().map(|r| tid_of(r.executor, lw_tid)).collect();
        tids.sort_unstable();
        tids.dedup();
        for &t in &tids {
            let name = if t == lw_tid { "lightweight".to_string() } else { format!("executor {t}") };
            b.thread_name(pid, t, &name);
        }

        let lifecycle_tid = lw_tid + 1;
        b.thread_name(pid, lifecycle_tid, "lifecycle");
        b.instant(pid, lifecycle_tid, s.start_us, "admitted", Json::obj());
        if let Some(first) = s.records.iter().map(|r| r.start_us).min_by(|a, b| a.total_cmp(b)) {
            b.instant(pid, lifecycle_tid, s.start_us + first, "started", Json::obj());
        }
        let mut targs = Json::obj();
        targs.set("cause", s.outcome.as_str());
        b.instant(pid, lifecycle_tid, s.end_us, &s.outcome, targs);

        for r in s.records {
            let node = s.graph.node(r.node);
            let mut args = Json::obj();
            args.set("node", r.node as u64).set("kind", node.kind.mnemonic());
            if let Some(levels) = s.levels {
                if let Some(&lv) = levels.get(r.node as usize) {
                    args.set("level", lv);
                }
            }
            b.span(
                pid,
                tid_of(r.executor, lw_tid),
                s.start_us + r.start_us,
                r.duration_us(),
                &node.name,
                node.kind.mnemonic(),
                args,
            );
        }
    }
    b.finish()
}

/// Counts extracted by [`validate_chrome_trace`], for test assertions.
#[derive(Debug, Clone)]
pub struct ChromeTraceStats {
    /// Distinct pids carrying `process_name` metadata.
    pub processes: usize,
    pub spans: usize,
    pub instants: usize,
    pub instant_names: std::collections::BTreeSet<String>,
}

/// Parse an exported Chrome trace document and check its well-formedness
/// invariants: every `X` span sits on a pid with `process_name` metadata
/// and a `(pid, tid)` with `thread_name` metadata, all timestamps are
/// finite, durations are non-negative, and spans on one `(pid, tid)` lane
/// never overlap.
pub fn validate_chrome_trace(text: &str) -> Result<ChromeTraceStats, String> {
    use std::collections::{BTreeMap, BTreeSet};
    let doc = crate::util::json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "missing traceEvents array".to_string())?;

    let num = |e: &Json, k: &str| -> Result<f64, String> {
        e.get(k).and_then(|v| v.as_f64()).ok_or_else(|| format!("event missing numeric {k:?}"))
    };

    let mut named_procs: BTreeSet<u64> = BTreeSet::new();
    let mut named_threads: BTreeSet<(u64, u64)> = BTreeSet::new();
    let mut spans: BTreeMap<(u64, u64), Vec<(f64, f64)>> = BTreeMap::new();
    let mut instants = 0usize;
    let mut instant_names: BTreeSet<String> = BTreeSet::new();

    for e in events {
        let ph = e.get("ph").and_then(|v| v.as_str()).ok_or_else(|| "event missing ph".to_string())?;
        let pid = num(e, "pid")? as u64;
        match ph {
            "M" => {
                match e.get("name").and_then(|v| v.as_str()).unwrap_or("") {
                    "process_name" => {
                        named_procs.insert(pid);
                    }
                    "thread_name" => {
                        named_threads.insert((pid, num(e, "tid")? as u64));
                    }
                    _ => {}
                }
            }
            "X" => {
                let tid = num(e, "tid")? as u64;
                let ts = num(e, "ts")?;
                let dur = num(e, "dur")?;
                if !ts.is_finite() || !dur.is_finite() {
                    return Err(format!("span has non-finite ts/dur ({ts}, {dur})"));
                }
                if dur < 0.0 {
                    return Err(format!("span has negative duration {dur}"));
                }
                spans.entry((pid, tid)).or_default().push((ts, ts + dur));
            }
            "i" | "I" => {
                let ts = num(e, "ts")?;
                if !ts.is_finite() {
                    return Err("instant has non-finite ts".to_string());
                }
                instants += 1;
                if let Some(n) = e.get("name").and_then(|v| v.as_str()) {
                    instant_names.insert(n.to_string());
                }
            }
            other => return Err(format!("unexpected event phase {other:?}")),
        }
    }

    let mut span_count = 0usize;
    for ((pid, tid), mut sp) in spans {
        if !named_procs.contains(&pid) {
            return Err(format!("spans on pid {pid} but no process_name metadata"));
        }
        if !named_threads.contains(&(pid, tid)) {
            return Err(format!("spans on pid {pid} tid {tid} but no thread_name metadata"));
        }
        sp.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in sp.windows(2) {
            if w[0].1 > w[1].0 + 1e-6 {
                return Err(format!(
                    "pid {pid} tid {tid}: spans overlap ([{:.3},{:.3}] vs [{:.3},{:.3}])",
                    w[0].0, w[0].1, w[1].0, w[1].1
                ));
            }
        }
        span_count += sp.len();
    }
    Ok(ChromeTraceStats { processes: named_procs.len(), spans: span_count, instants, instant_names })
}

fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

/// Validate a record set against the graph: every op exactly once,
/// dependencies respected, per-executor serialization, makespan agrees.
pub fn validate_records(graph: &Graph, records: &[OpRecord], makespan_us: f64) -> Result<(), String> {
    if records.len() != graph.len() {
        return Err(format!("{} records for {} nodes", records.len(), graph.len()));
    }
    let mut end_of = vec![f64::NAN; graph.len()];
    let mut start_of = vec![f64::NAN; graph.len()];
    for r in records {
        if (r.node as usize) >= graph.len() {
            return Err(format!("record for unknown node {}", r.node));
        }
        // Non-finite timestamps must be rejected up front: a NaN start
        // would sail through every later comparison (all false).
        if !r.start_us.is_finite() || !r.end_us.is_finite() {
            return Err(format!(
                "node {} has non-finite times [{}, {}]",
                r.node, r.start_us, r.end_us
            ));
        }
        if !end_of[r.node as usize].is_nan() {
            return Err(format!("node {} executed twice", r.node));
        }
        if r.end_us < r.start_us {
            return Err(format!("node {} ends before it starts", r.node));
        }
        end_of[r.node as usize] = r.end_us;
        start_of[r.node as usize] = r.start_us;
    }
    const EPS: f64 = 1e-6;
    for v in 0..graph.len() as NodeId {
        for &p in graph.preds(v) {
            if end_of[p as usize] > start_of[v as usize] + EPS {
                return Err(format!(
                    "dependency violated: {} (ends {:.3}) must finish before {} (starts {:.3})",
                    graph.node(p).name,
                    end_of[p as usize],
                    graph.node(v).name,
                    start_of[v as usize],
                ));
            }
        }
    }
    // per-executor non-overlap
    let mut by_exec: std::collections::BTreeMap<u32, Vec<&OpRecord>> = Default::default();
    for r in records {
        by_exec.entry(r.executor).or_default().push(r);
    }
    for (e, mut rs) in by_exec {
        rs.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        for w in rs.windows(2) {
            if w[0].end_us > w[1].start_us + EPS {
                return Err(format!(
                    "executor {e} overlap: node {} [{:.3},{:.3}] vs node {} [{:.3},{:.3}]",
                    w[0].node, w[0].start_us, w[0].end_us, w[1].node, w[1].start_us, w[1].end_us
                ));
            }
        }
    }
    let max_end = records.iter().map(|r| r.end_us).fold(0.0, f64::max);
    if (max_end - makespan_us).abs() > 1e-3 {
        return Err(format!("makespan {makespan_us} != last end {max_end}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    fn chain() -> Graph {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let c = b.add("c", OpKind::Scalar);
        b.depend(a, c);
        b.build().unwrap()
    }

    fn good_records() -> Vec<OpRecord> {
        vec![
            OpRecord { node: 0, executor: 0, start_us: 0.0, end_us: 1.0 },
            OpRecord { node: 1, executor: 1, start_us: 1.0, end_us: 3.0 },
        ]
    }

    #[test]
    fn valid_records_pass() {
        validate_records(&chain(), &good_records(), 3.0).unwrap();
    }

    #[test]
    fn dependency_violation_caught() {
        let mut rs = good_records();
        rs[1].start_us = 0.5;
        rs[1].end_us = 3.0;
        assert!(validate_records(&chain(), &rs, 3.0).is_err());
    }

    #[test]
    fn executor_overlap_caught() {
        let g = {
            let mut b = GraphBuilder::new();
            b.add("a", OpKind::Scalar);
            b.add("b", OpKind::Scalar);
            b.build().unwrap()
        };
        let rs = vec![
            OpRecord { node: 0, executor: 0, start_us: 0.0, end_us: 2.0 },
            OpRecord { node: 1, executor: 0, start_us: 1.0, end_us: 3.0 },
        ];
        assert!(validate_records(&g, &rs, 3.0).unwrap_err().contains("overlap"));
    }

    #[test]
    fn missing_and_duplicate_records_caught() {
        assert!(validate_records(&chain(), &good_records()[..1], 1.0).is_err());
        let rs = vec![
            OpRecord { node: 0, executor: 0, start_us: 0.0, end_us: 1.0 },
            OpRecord { node: 0, executor: 1, start_us: 1.0, end_us: 2.0 },
        ];
        assert!(validate_records(&chain(), &rs, 2.0).is_err());
    }

    #[test]
    fn wrong_makespan_caught() {
        assert!(validate_records(&chain(), &good_records(), 99.0).is_err());
    }

    #[test]
    fn non_finite_records_rejected() {
        // A NaN start used to slip through the dependency check because
        // every NaN comparison is false.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut rs = good_records();
            rs[1].start_us = bad;
            let err = validate_records(&chain(), &rs, 3.0).unwrap_err();
            assert!(err.contains("non-finite"), "{bad}: {err}");
            let mut rs = good_records();
            rs[0].end_us = bad;
            let err = validate_records(&chain(), &rs, 3.0).unwrap_err();
            assert!(err.contains("non-finite"), "{bad}: {err}");
        }
    }

    #[test]
    fn chrome_json_parses_and_validates() {
        let g = chain();
        let t = Trace { records: good_records() };
        let text = t.to_chrome_json(&g);
        let doc = crate::util::json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let xs: Vec<_> =
            events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")).collect();
        assert_eq!(xs.len(), 2);
        let stats = validate_chrome_trace(&text).unwrap();
        assert_eq!(stats.processes, 1);
        assert_eq!(stats.spans, 2);
    }

    #[test]
    fn lightweight_tid_sits_above_real_executors() {
        // Executor id 9999 is real here; the lightweight lane must not
        // collide with it (it used to be hardcoded to 9999).
        let g = chain();
        let t = Trace {
            records: vec![
                OpRecord { node: 0, executor: 9999, start_us: 0.0, end_us: 1.0 },
                OpRecord { node: 1, executor: LIGHTWEIGHT_EXECUTOR, start_us: 1.0, end_us: 2.0 },
            ],
        };
        let text = t.to_chrome_json(&g);
        let doc = crate::util::json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let tid_of_span = |name: &str| -> u64 {
            events
                .iter()
                .find(|e| {
                    e.get("ph").unwrap().as_str() == Some("X")
                        && e.get("name").unwrap().as_str() == Some(name)
                })
                .and_then(|e| e.get("tid").unwrap().as_f64())
                .unwrap() as u64
        };
        assert_eq!(tid_of_span("a"), 9999);
        assert_eq!(tid_of_span("c"), 10000);
        let lw_meta = events.iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some("M")
                && e.get("name").unwrap().as_str() == Some("thread_name")
                && e.get("tid").unwrap().as_f64() == Some(10000.0)
                && e.get("args").unwrap().get("name").unwrap().as_str() == Some("lightweight")
        });
        assert!(lw_meta, "lightweight lane must carry thread_name metadata");
        validate_chrome_trace(&text).unwrap();
    }

    #[test]
    fn ascii_render_handles_tiny_widths() {
        let g = chain();
        let t = Trace { records: good_records() };
        // width 0 used to underflow-panic on `width - 1`
        let art = t.render_ascii(&g, 0);
        assert!(art.contains("makespan"));
        let art = t.render_ascii(&g, 1);
        assert!(art.contains("e00") && art.contains("e01"));
    }

    #[test]
    fn ascii_render_handles_zero_makespan() {
        // A single zero-duration op: makespan 0 used to produce NaN
        // column indices.
        let g = chain();
        let t = Trace {
            records: vec![
                OpRecord { node: 0, executor: 0, start_us: 0.0, end_us: 0.0 },
                OpRecord { node: 1, executor: 0, start_us: 0.0, end_us: 0.0 },
            ],
        };
        let art = t.render_ascii(&g, 10);
        assert!(art.contains("e00"));
        assert!(art.contains("makespan"));
    }

    #[test]
    fn session_export_validates_with_metadata_and_instants() {
        let g = chain();
        let levels = [2.0, 1.0];
        let recs = good_records();
        let sessions = [
            SessionTraceExport {
                label: "session 1 (chain)".to_string(),
                graph: &g,
                levels: Some(&levels),
                records: &recs,
                start_us: 0.0,
                end_us: 3.0,
                outcome: "done".to_string(),
            },
            SessionTraceExport {
                label: "session 2 (chain)".to_string(),
                graph: &g,
                levels: None,
                records: &recs,
                start_us: 5.0,
                end_us: 8.0,
                outcome: "failed".to_string(),
            },
        ];
        let fleet_events = [
            FleetEvent {
                t_us: 0.5,
                executor: 0,
                kind: FleetEventKind::Steal { session: 2, cross_domain: true },
            },
            FleetEvent { t_us: 1.5, executor: 1, kind: FleetEventKind::Park },
            FleetEvent {
                t_us: 2.0,
                executor: FLEET_LANE,
                kind: FleetEventKind::ModeSwitch {
                    from: DispatchMode::Centralized,
                    to: DispatchMode::Decentralized,
                },
            },
        ];
        let text = export_chrome_trace(&sessions, &fleet_events, 2);
        let stats = validate_chrome_trace(&text).unwrap();
        assert_eq!(stats.processes, 3, "fleet + two sessions");
        assert_eq!(stats.spans, 4);
        for name in ["steal", "park", "mode_switch", "admitted", "started", "done", "failed"] {
            assert!(stats.instant_names.contains(name), "missing instant {name:?}");
        }
        // level rides along in span args when levels are provided
        let doc = crate::util::json::parse(&text).unwrap();
        let has_level = doc.get("traceEvents").unwrap().as_arr().unwrap().iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some("X")
                && e.get("args").and_then(|a| a.get("level")).is_some()
        });
        assert!(has_level);
    }

    #[test]
    fn validator_rejects_overlap_and_missing_metadata() {
        let mut b = ChromeTraceBuilder::new();
        b.process_name(1, "p");
        b.thread_name(1, 0, "t");
        b.span(1, 0, 0.0, 2.0, "a", "k", Json::obj());
        b.span(1, 0, 1.0, 2.0, "b", "k", Json::obj());
        assert!(validate_chrome_trace(&b.finish()).unwrap_err().contains("overlap"));

        let mut b = ChromeTraceBuilder::new();
        b.span(1, 0, 0.0, 1.0, "a", "k", Json::obj());
        assert!(validate_chrome_trace(&b.finish()).unwrap_err().contains("process_name"));

        let mut b = ChromeTraceBuilder::new();
        b.process_name(1, "p");
        b.span(1, 0, 0.0, 1.0, "a", "k", Json::obj());
        assert!(validate_chrome_trace(&b.finish()).unwrap_err().contains("thread_name"));

        let mut b = ChromeTraceBuilder::new();
        b.process_name(1, "p");
        b.thread_name(1, 0, "t");
        b.span(1, 0, 0.0, f64::NAN, "a", "k", Json::obj());
        // NaN serializes as null, which fails the numeric-field check
        assert!(validate_chrome_trace(&b.finish()).is_err());
    }

    #[test]
    fn correlation_of_ordered_chain_is_one() {
        let g = chain();
        let t = Trace { records: good_records() };
        let c = t.depth_time_correlation(&g);
        assert!((c - 1.0).abs() < 1e-9, "correlation {c}");
    }

    #[test]
    fn ascii_render_mentions_executors() {
        let g = chain();
        let t = Trace { records: good_records() };
        let art = t.render_ascii(&g, 40);
        assert!(art.contains("e00"));
        assert!(art.contains("e01"));
        assert!(art.contains("makespan"));
    }
}
