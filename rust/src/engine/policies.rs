//! Ready-operation ordering policies.
//!
//! §4.3: "the centralized scheduler … gives us flexibility to use different
//! advanced scheduler polices. Current scheduling strategy is critical-path
//! first, but the architecture allows us to easily implement other
//! strategies." The ablation bench compares these.

/// How the scheduler orders ready operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's strategy: highest level value (longest remaining
    /// critical path) first.
    CriticalPathFirst,
    /// FIFO by readiness time — what the naive shared-queue engines do.
    Fifo,
    /// LIFO (depth-first-ish) — included to show ordering matters.
    Lifo,
    /// Uniformly random among ready ops.
    Random,
    /// Smallest level first (adversarial; worst case for the chain bound).
    AntiCritical,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::CriticalPathFirst => "cp-first",
            Policy::Fifo => "fifo",
            Policy::Lifo => "lifo",
            Policy::Random => "random",
            Policy::AntiCritical => "anti-critical",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "cp-first" | "cp_first" | "critical-path" | "cpf" => Some(Policy::CriticalPathFirst),
            "fifo" => Some(Policy::Fifo),
            "lifo" => Some(Policy::Lifo),
            "random" => Some(Policy::Random),
            "anti-critical" | "anti" => Some(Policy::AntiCritical),
            _ => None,
        }
    }

    pub fn all() -> [Policy; 5] {
        [
            Policy::CriticalPathFirst,
            Policy::Fifo,
            Policy::Lifo,
            Policy::Random,
            Policy::AntiCritical,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        for p in Policy::all() {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("bogus"), None);
    }
}
