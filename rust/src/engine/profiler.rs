//! The Graphi profiler (§4.2).
//!
//! Two jobs:
//!
//! 1. **Configuration search** — enumerate the symmetric
//!    `(executors × threads)` combinations (plus model-specific extras like
//!    PathNet's 6×10), run a few iterations of each, keep the one with
//!    minimal makespan.
//! 2. **Duration estimation** — record per-op start/end over the first few
//!    iterations and average, feeding the critical-path level values used
//!    by the scheduler. Profiling noise is part of the simulation, so
//!    averaging genuinely reduces variance here, like in the real system.

use crate::graph::Graph;
use crate::sim::topology::candidate_configs;
use crate::util::stats::Welford;

use super::graphi::GraphiEngine;
use super::{DispatchMode, Engine, RunResult, SimEnv};

/// Profiler configuration.
#[derive(Debug, Clone)]
pub struct Profiler {
    /// Iterations per candidate configuration.
    pub iterations: usize,
    /// Worker cores to split among executors (machine cores − 2 reserved).
    pub worker_cores: usize,
    /// Extra model-specific configurations to try (e.g. `(6,10)`).
    pub extra_configs: Vec<(usize, usize)>,
}

impl Default for Profiler {
    fn default() -> Self {
        Profiler { iterations: 3, worker_cores: 64, extra_configs: Vec::new() }
    }
}

/// One candidate's measurements.
#[derive(Debug, Clone)]
pub struct ConfigMeasurement {
    pub executors: usize,
    pub threads_per: usize,
    /// Dispatch architecture measured. The flat profiler only sweeps the
    /// paper's centralized design; the autotuner searches both.
    pub dispatch: DispatchMode,
    pub mean_makespan_us: f64,
    pub std_us: f64,
}

/// Search result.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub measurements: Vec<ConfigMeasurement>,
    pub best: (usize, usize),
    /// Averaged per-op durations at the best configuration, µs — the
    /// estimates the scheduler's level values are computed from.
    pub durations_us: Vec<f64>,
}

impl Profiler {
    /// Enumerate candidates: powers of two (§4.2's example) plus extras,
    /// via the shared [`candidate_configs`] enumeration the autotuner also
    /// searches.
    pub fn candidates(&self) -> Vec<(usize, usize)> {
        candidate_configs(self.worker_cores, &self.extra_configs)
    }

    /// Run the search.
    pub fn profile(&self, graph: &Graph, env: &SimEnv) -> ProfileReport {
        let mut measurements = Vec::new();
        for (executors, threads_per) in self.candidates() {
            let mut acc = Welford::new();
            for iter in 0..self.iterations {
                let env_i = SimEnv { cost: env.cost.clone(), seed: env.seed ^ (iter as u64) << 8 };
                let result = GraphiEngine::new(executors, threads_per).run(graph, &env_i);
                acc.push(result.makespan_us);
            }
            measurements.push(ConfigMeasurement {
                executors,
                threads_per,
                dispatch: DispatchMode::Centralized,
                mean_makespan_us: acc.mean(),
                std_us: acc.std(),
            });
        }
        let best = measurements
            .iter()
            .min_by(|a, b| a.mean_makespan_us.total_cmp(&b.mean_makespan_us))
            .expect("at least one candidate");
        let best_pair = (best.executors, best.threads_per);
        let durations_us = self.estimate_durations(graph, env, best_pair.1);
        ProfileReport { measurements, best: best_pair, durations_us }
    }

    /// Average measured per-op durations over `iterations` runs at the
    /// chosen team size (§5.2: "averaged over multiple iterations to
    /// reduce variance").
    pub fn estimate_durations(&self, graph: &Graph, env: &SimEnv, threads_per: usize) -> Vec<f64> {
        let executors = (self.worker_cores / threads_per).max(1);
        let mut acc: Vec<Welford> = vec![Welford::new(); graph.len()];
        for iter in 0..self.iterations {
            let env_i = SimEnv { cost: env.cost.clone(), seed: env.seed ^ 0xABCD ^ (iter as u64) << 16 };
            let result: RunResult = GraphiEngine::new(executors, threads_per).run(graph, &env_i);
            for r in &result.records {
                acc[r.node as usize].push(r.duration_us());
            }
        }
        let mut durations: Vec<f64> = acc.into_iter().map(|w| w.mean()).collect();
        let clamped = sanitize_durations(&mut durations);
        if clamped > 0 {
            crate::log_warn!(
                "profiler: clamped {clamped} non-finite/negative op duration estimate(s) to 0"
            );
        }
        durations
    }

    /// Render the search as a table.
    pub fn render(report: &ProfileReport) -> String {
        let mut t = crate::util::table::Table::new(&["config", "mean makespan", "std"]);
        for m in &report.measurements {
            let marker = if (m.executors, m.threads_per) == report.best { " *" } else { "" };
            t.row(&[
                format!("{}x{}{}", m.executors, m.threads_per, marker),
                crate::util::fmt_us(m.mean_makespan_us),
                crate::util::fmt_us(m.std_us),
            ]);
        }
        t.render()
    }
}

/// Clamp non-finite or negative duration estimates to 0 in place,
/// returning how many were touched. A NaN level value would poison every
/// downstream critical-path comparison (`quantize` in
/// [`super::ready`] orders keys by the raw float), and a negative one
/// would invert CP ordering — an op a profiling run never produced a
/// record for (e.g. a faulted iteration) must degrade to "no estimated
/// weight", not to garbage keys.
pub fn sanitize_durations(durations: &mut [f64]) -> usize {
    let mut clamped = 0usize;
    for d in durations.iter_mut() {
        if !d.is_finite() || *d < 0.0 {
            *d = 0.0;
            clamped += 1;
        }
    }
    clamped
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{self, ModelKind, ModelSize};

    #[test]
    fn candidates_include_extras() {
        let p = Profiler { extra_configs: vec![(6, 10)], ..Default::default() };
        let c = p.candidates();
        assert!(c.contains(&(1, 64)));
        assert!(c.contains(&(6, 10)));
    }

    #[test]
    fn profile_picks_parallel_config_for_lstm() {
        // §7.3: LSTM's best configuration is parallel (8–16 executors),
        // never the single-executor one.
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let p = Profiler { iterations: 1, ..Default::default() };
        let report = p.profile(&g, &SimEnv::knl(1));
        assert!(report.best.0 > 1, "best config {:?} must be parallel", report.best);
        assert_eq!(report.durations_us.len(), g.len());
    }

    #[test]
    fn durations_are_positive() {
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let p = Profiler { iterations: 2, ..Default::default() };
        let d = p.estimate_durations(&g, &SimEnv::knl(2), 8);
        assert!(d.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn sanitize_clamps_only_the_broken_estimates() {
        let mut d = vec![1.5, f64::NAN, -0.25, f64::INFINITY, 0.0, f64::NEG_INFINITY, 3.0];
        assert_eq!(sanitize_durations(&mut d), 4);
        assert_eq!(d, vec![1.5, 0.0, 0.0, 0.0, 0.0, 0.0, 3.0]);
        // a clean slice is untouched and reports zero
        assert_eq!(sanitize_durations(&mut d), 0);
    }

    #[test]
    fn render_marks_best() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let p = Profiler { iterations: 1, ..Default::default() };
        let report = p.profile(&g, &SimEnv::knl(3));
        assert!(Profiler::render(&report).contains('*'));
    }
}
