//! Bounded lock-free multi-producer/single-consumer completion queue.
//!
//! The threaded engine's executors used to report completions through
//! per-executor SPSC "triggered queues" that the scheduler scanned in a
//! round-robin every loop iteration — an O(executors) poll that loads one
//! shared cache line per executor even when nothing completed. This queue
//! replaces the scan: all executors push `(executor, node)` completions
//! into **one** bounded queue and the scheduler pops (optionally in
//! batches), so an idle poll is a single acquire load and a completion
//! burst drains contiguously.
//!
//! The algorithm is Dmitry Vyukov's bounded MPMC queue, specialised to a
//! single consumer: each slot carries a sequence number that encodes
//! whether it is ready to write (`seq == pos`) or ready to read
//! (`seq == pos + 1`); producers claim slots with a CAS on `enqueue_pos`,
//! and — because only one thread ever pops — the consumer advances
//! `dequeue_pos` with a plain store instead of a CAS.
//!
//! Both cursors live on their own 64-byte-aligned cache lines so producer
//! CAS traffic does not bounce the consumer's cursor line.
//!
//! # Safety contract
//!
//! Any number of threads may call [`MpscQueue::push`] concurrently; at
//! most one thread may call [`MpscQueue::pop`]/[`MpscQueue::pop_batch`] at
//! a time. The threaded engine upholds this: executors are the producers,
//! the scheduler thread the sole consumer.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

/// An atomic cursor on its own cache line.
#[repr(align(64))]
struct PaddedAtomicUsize(AtomicUsize);

struct Slot<T> {
    /// Vyukov sequence stamp: `pos` when free, `pos + 1` when occupied,
    /// `pos + capacity` after the consumer recycles the slot.
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Fixed-capacity MPSC queue. Capacity is rounded up to a power of two
/// (minimum 2); unlike [`super::ring::SpscRing`] no slot is sacrificed.
pub struct MpscQueue<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    enqueue_pos: PaddedAtomicUsize,
    dequeue_pos: PaddedAtomicUsize,
}

// SAFETY: slot ownership is handed between threads through the `seq`
// acquire/release protocol; a slot's value is only touched by the thread
// that claimed it (producer via CAS, the single consumer via its cursor).
unsafe impl<T: Send> Send for MpscQueue<T> {}
unsafe impl<T: Send> Sync for MpscQueue<T> {}

impl<T> MpscQueue<T> {
    /// Create a queue holding at least `capacity` items.
    pub fn new(capacity: usize) -> MpscQueue<T> {
        let cap = capacity.max(2).next_power_of_two();
        let buf: Vec<Slot<T>> = (0..cap)
            .map(|i| Slot { seq: AtomicUsize::new(i), val: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect();
        MpscQueue {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            enqueue_pos: PaddedAtomicUsize(AtomicUsize::new(0)),
            dequeue_pos: PaddedAtomicUsize(AtomicUsize::new(0)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Push an item; returns `Err(item)` if the queue is full. Safe to
    /// call from any number of threads.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut pos = self.enqueue_pos.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = (seq as isize).wrapping_sub(pos as isize);
            if dif == 0 {
                // slot free at our position: claim it
                match self.enqueue_pos.0.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS makes this thread the slot's
                        // unique owner until the seq store below.
                        unsafe {
                            (*slot.val.get()).write(item);
                        }
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(current) => pos = current,
                }
            } else if dif < 0 {
                // slot still holds an unconsumed item from a lap ago
                return Err(item);
            } else {
                // another producer claimed this slot; reload the cursor
                pos = self.enqueue_pos.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop the oldest item, if any. **Single consumer only.**
    pub fn pop(&self) -> Option<T> {
        let pos = self.dequeue_pos.0.load(Ordering::Relaxed);
        let slot = &self.buf[pos & self.mask];
        let seq = slot.seq.load(Ordering::Acquire);
        let dif = (seq as isize).wrapping_sub(pos.wrapping_add(1) as isize);
        if dif < 0 {
            return None; // next slot not yet published
        }
        debug_assert!(dif == 0, "multiple consumers detected");
        // single consumer: a plain store advances the cursor, no CAS
        self.dequeue_pos.0.store(pos.wrapping_add(1), Ordering::Relaxed);
        // SAFETY: seq == pos + 1 ⇒ the producer's release store published
        // this slot's value, and only this (sole) consumer reads it.
        let item = unsafe { (*slot.val.get()).assume_init_read() };
        slot.seq.store(pos.wrapping_add(self.mask + 1), Ordering::Release);
        Some(item)
    }

    /// Pop up to `max` items into `out`; returns the number popped.
    /// **Single consumer only.**
    pub fn pop_batch(&self, out: &mut Vec<T>, max: usize) -> usize {
        let mut popped = 0usize;
        while popped < max {
            match self.pop() {
                Some(item) => {
                    out.push(item);
                    popped += 1;
                }
                None => break,
            }
        }
        popped
    }

    /// Whether the queue currently looks empty (racy under concurrency).
    pub fn is_empty(&self) -> bool {
        let pos = self.dequeue_pos.0.load(Ordering::Relaxed);
        let seq = self.buf[pos & self.mask].seq.load(Ordering::Acquire);
        (seq as isize).wrapping_sub(pos.wrapping_add(1) as isize) < 0
    }
}

impl<T> Drop for MpscQueue<T> {
    fn drop(&mut self) {
        // `&mut self` ⇒ no concurrent access; drain undelivered items
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = MpscQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn capacity_and_fullness() {
        let q = MpscQueue::new(3); // rounds up to 4
        assert_eq!(q.capacity(), 4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.push(99), Err(99));
        assert_eq!(q.pop(), Some(0));
        q.push(4).unwrap();
        for i in 1..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn wraparound_many_laps() {
        let q = MpscQueue::new(2);
        for i in 0..1000 {
            q.push(i).unwrap();
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn pop_batch_drains_in_order() {
        let q = MpscQueue::new(16);
        for i in 0..10 {
            q.push(i).unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(q.pop_batch(&mut out, 4), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(q.pop_batch(&mut out, 100), 6);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
        assert_eq!(q.pop_batch(&mut out, 1), 0);
    }

    #[test]
    fn multi_producer_stress() {
        let q = Arc::new(MpscQueue::<(usize, u64)>::new(64));
        let producers = 4usize;
        let per = 25_000u64;
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let mut item = (p, i);
                    loop {
                        match q.push(item) {
                            Ok(()) => break,
                            Err(back) => {
                                item = back;
                                std::hint::spin_loop();
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            }));
        }
        // consume on this thread: per-producer streams must arrive in order
        let mut next_expected = vec![0u64; producers];
        let mut total = 0u64;
        let target = producers as u64 * per;
        while total < target {
            if let Some((p, i)) = q.pop() {
                assert_eq!(i, next_expected[p], "producer {p} stream reordered");
                next_expected[p] += 1;
                total += 1;
            } else {
                std::hint::spin_loop();
                std::thread::yield_now();
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn drops_not_leaked() {
        use std::rc::Rc;
        let flag = Rc::new(());
        let q = MpscQueue::new(4);
        q.push(Rc::clone(&flag)).unwrap();
        q.push(Rc::clone(&flag)).unwrap();
        assert_eq!(Rc::strong_count(&flag), 3);
        drop(q);
        assert_eq!(Rc::strong_count(&flag), 1);
    }
}
